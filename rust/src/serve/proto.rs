//! `mmbsgd serve` line protocol: a std-only TCP server over the
//! micro-batching engine.
//!
//! ## Protocol
//!
//! Newline-delimited UTF-8 commands; one reply line per command, in
//! request order per connection.  Fields are space-separated; decision
//! values print with Rust's shortest round-trip `f64` formatting, so a
//! client parsing the reply recovers the served bits exactly.
//!
//! ```text
//! predict [key=K] v1 .. vd     ->  ok <+1|-1> <decision> <model>@v<N>
//! decision [key=K] v1 .. vd    ->  ok <decision> <model>@v<N>
//! feedback [key=K] <±1> v1..vd ->  ok <hit|miss> <decision> <model>@v<N>
//! stats                        ->  ok served=.. shed=.. queued=.. batches=..
//!                                  mean_batch=.. low_margin=.. mean_margin=..
//!                                  window_acc=.. feedback=.. expired=..
//!                                  idle_timeout=.. oversize=.. busy=.. models=..
//! swap-model <name> <path>     ->  ok <name>@v<N>
//! push-artifact <len>          ->  ok staged <name>@v<N> dim=<d> nsv=<n>
//!   (then exactly <len> payload bytes — a fleet artifact bundle)
//! activate <name>@v<N>         ->  ok active <name>@v<N> registry=v<R>
//! rollback <name>              ->  ok rollback <name>@v<N> registry=v<R>
//! fleet-status                 ->  ok fleet models=.. staged=.. acc=..
//! shutdown                     ->  ok bye          (then the server exits)
//! auth <token>                 ->  ok authed       (see below)
//! <anything malformed>         ->  err <reason>    (connection stays up)
//! ```
//!
//! When [`ServeOptions::auth_token`] is set, `auth <token>` must be a
//! connection's **first** command: anything else answers
//! `err unauthorized` and the connection closes (the handshake is
//! handled connection-side, so an unauthenticated peer never reaches
//! the engine).  After a successful handshake a repeated `auth` is a
//! `BadRequest` like any other malformed line.  The HTTP front end
//! enforces the same token per request via `Authorization: Bearer`.
//!
//! `key=K` drives [`super::ModelRegistry`]'s deterministic A/B routing
//! (same key ⇒ same model); unkeyed requests route on their request id.
//! `swap-model` hot-swaps a model file under an *existing* registry
//! name and bumps its version — in-flight requests drain against the
//! old model first, so no request is answered by a half-installed
//! model.
//!
//! The four fleet verbs are live only on [`serve_fleet`] servers,
//! which carry a [`FleetHandler`] (see
//! [`crate::fleet::ReplicaState`]); a plain [`serve`] answers them
//! `err fleet verbs not enabled`.  `push-artifact` is the protocol's
//! one length-delimited command: the connection reader consumes
//! exactly `<len>` payload bytes after the header line (so bundles
//! may contain newlines), and a connection that dies mid-payload
//! stages nothing.  Like `swap-model`, every fleet verb drains
//! in-flight requests first.
//!
//! ## Threading
//!
//! The same no-dependency scoped-thread idiom as
//! [`crate::runtime::pool`]: backends are deliberately not `Send`, so
//! the engine — sole owner of the registry — runs on [`serve`]'s
//! calling thread, while `std::thread::scope` owns the accept loop and
//! a reader/writer pair per connection, all borrowing the stop flag —
//! no `Arc`, no detached threads, everything joined before [`serve`]
//! returns.  Readers parse lines into [`Command`]s and send them over
//! an mpsc channel without waiting for answers; the engine drains the
//! channel in arrival order, coalescing consecutive query commands
//! into [`super::BatchEngine`] micro-batches (the batch is "whatever
//! arrived while the last margins pass ran"), and routes replies back
//! through per-connection channels.  The kernel compute itself is
//! sharded by the registry backend's [`crate::runtime::WorkerPool`]
//! (`--threads`).  Replies are emitted in request-id order, so
//! per-connection pipelining is FIFO even though batches group by
//! model.

use super::batch::{BatchEngine, EngineStats};
use super::http;
use super::metrics::ServeMetrics;
use super::monitor::{DegradeTotals, DriftReport, Monitor};
use super::registry::ModelRegistry;
use super::ShedPolicy;
use crate::error::ServeError;
use crate::model::SvmModel;
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// How long a blocked connection read waits before re-checking the
/// stop flag (also the accept-poll interval).
pub(crate) const POLL: Duration = Duration::from_millis(50);

/// Per-connection bound on answered-but-unwritten reply lines.  The
/// request side is bounded by the engine queue (`queue_max` + shed
/// policy); this bounds the *reply* side against a client that
/// pipelines requests but never reads its socket.  Replies beyond the
/// backlog are dropped (the connection is already desynced — such a
/// client has violated the one-reply-per-line contract by orders of
/// magnitude), keeping server memory bounded per connection.
pub(crate) const REPLY_BACKLOG: usize = 1024;

/// A parsed protocol command.
#[derive(Clone, Debug, PartialEq)]
pub enum Command {
    Predict { key: Option<String>, x: Vec<f32> },
    Decision { key: Option<String>, x: Vec<f32> },
    Feedback { key: Option<String>, y: f32, x: Vec<f32> },
    Stats,
    SwapModel { name: String, path: String },
    /// Fleet verb: a fully-received artifact bundle to stage (the
    /// connection reader already consumed the length-delimited
    /// payload; see the module docs).
    PushArtifact { payload: String },
    /// Fleet verb: activate a staged `<name>@v<version>` bundle.
    Activate { name: String, version: u64 },
    /// Fleet verb: restore `<name>`'s last-good generation.
    Rollback { name: String },
    /// Fleet verb: one-line replica fleet status.
    FleetStatus,
    Shutdown,
}

/// Parse a `push-artifact <len>` header line's payload length.
/// Returns `None` when the line is not a push-artifact header at all.
fn parse_push_header(line: &str) -> Option<Result<usize, ServeError>> {
    let mut it = line.split_ascii_whitespace();
    if it.next() != Some("push-artifact") {
        return None;
    }
    let Some(len_tok) = it.next() else {
        return Some(Err(ServeError::BadRequest("push-artifact needs <len>".into())));
    };
    if it.next().is_some() {
        return Some(Err(ServeError::BadRequest(
            "push-artifact takes exactly one <len> argument".into(),
        )));
    }
    match len_tok.parse::<usize>() {
        Ok(n) if n > 0 => Some(Ok(n)),
        Ok(_) => Some(Err(ServeError::BadRequest("push-artifact payload is empty".into()))),
        Err(_) => {
            Some(Err(ServeError::BadRequest(format!("bad push-artifact length {len_tok:?}"))))
        }
    }
}

/// Parse one protocol line.  Pure function — every malformation is a
/// [`ServeError::BadRequest`] carrying the reason for the `err` reply.
pub fn parse_line(line: &str) -> Result<Command, ServeError> {
    let mut it = line.split_ascii_whitespace();
    let cmd = it.next().ok_or_else(|| ServeError::BadRequest("empty command".into()))?;
    match cmd {
        "predict" | "decision" | "feedback" => {
            let mut rest: Vec<&str> = it.collect();
            let key = rest.first().and_then(|t| t.strip_prefix("key=")).map(str::to_string);
            if key.is_some() {
                rest.remove(0);
            }
            let y = if cmd == "feedback" {
                if rest.is_empty() {
                    return Err(ServeError::BadRequest("feedback needs a ±1 label".into()));
                }
                let tok = rest.remove(0);
                match tok {
                    "+1" | "1" => 1.0f32,
                    "-1" => -1.0,
                    other => {
                        return Err(ServeError::BadRequest(format!(
                            "feedback label must be +1 or -1, got {other:?}"
                        )))
                    }
                }
            } else {
                0.0
            };
            if rest.is_empty() {
                return Err(ServeError::BadRequest(format!("{cmd} needs feature values")));
            }
            let mut x = Vec::with_capacity(rest.len());
            for tok in rest {
                let v: f32 = tok.parse().map_err(|_| {
                    ServeError::BadRequest(format!("bad feature value {tok:?}"))
                })?;
                if !v.is_finite() {
                    return Err(ServeError::BadRequest(format!(
                        "feature value {tok:?} is not finite"
                    )));
                }
                x.push(v);
            }
            Ok(match cmd {
                "predict" => Command::Predict { key, x },
                "decision" => Command::Decision { key, x },
                _ => Command::Feedback { key, y, x },
            })
        }
        "stats" => match it.next() {
            None => Ok(Command::Stats),
            Some(extra) => {
                Err(ServeError::BadRequest(format!("stats takes no arguments, got {extra:?}")))
            }
        },
        "swap-model" => {
            let name = it
                .next()
                .ok_or_else(|| ServeError::BadRequest("swap-model needs <name> <path>".into()))?;
            let path = it
                .next()
                .ok_or_else(|| ServeError::BadRequest("swap-model needs <name> <path>".into()))?;
            if it.next().is_some() {
                return Err(ServeError::BadRequest(
                    "swap-model takes exactly <name> <path> (paths with spaces unsupported)"
                        .into(),
                ));
            }
            Ok(Command::SwapModel { name: name.into(), path: path.into() })
        }
        // The reader consumes push-artifact headers (and their payload
        // bytes) before lines reach the parser; one arriving here is a
        // header the reader rejected already or an out-of-context use.
        "push-artifact" => Err(ServeError::BadRequest(
            "push-artifact is length-delimited and must precede its payload bytes".into(),
        )),
        "activate" => {
            let spec = it
                .next()
                .ok_or_else(|| ServeError::BadRequest("activate needs <name>@v<version>".into()))?;
            if it.next().is_some() {
                return Err(ServeError::BadRequest(
                    "activate takes exactly one <name>@v<version> argument".into(),
                ));
            }
            let (name, ver) = spec.split_once('@').ok_or_else(|| {
                ServeError::BadRequest(format!("activate spec {spec:?} missing '@'"))
            })?;
            let ver = ver.strip_prefix('v').unwrap_or(ver);
            let version: u64 = ver.parse().map_err(|_| {
                ServeError::BadRequest(format!("bad activate version {ver:?} in {spec:?}"))
            })?;
            if name.is_empty() {
                return Err(ServeError::BadRequest(format!("activate spec {spec:?} has no name")));
            }
            Ok(Command::Activate { name: name.into(), version })
        }
        "rollback" => {
            let name = it
                .next()
                .ok_or_else(|| ServeError::BadRequest("rollback needs <name>".into()))?;
            if it.next().is_some() {
                return Err(ServeError::BadRequest(
                    "rollback takes exactly one <name> argument".into(),
                ));
            }
            Ok(Command::Rollback { name: name.into() })
        }
        "fleet-status" => match it.next() {
            None => Ok(Command::FleetStatus),
            Some(extra) => Err(ServeError::BadRequest(format!(
                "fleet-status takes no arguments, got {extra:?}"
            ))),
        },
        "shutdown" => Ok(Command::Shutdown),
        other => Err(ServeError::BadRequest(format!("unknown command {other:?}"))),
    }
}

/// Server knobs (`[serve]` TOML section / CLI flags).
#[derive(Clone, Debug, PartialEq)]
pub struct ServeOptions {
    /// Max rows per margins pass.
    pub batch_max: usize,
    /// Max admitted-but-unanswered requests.
    pub queue_max: usize,
    /// Who loses when the queue is full.
    pub shed: ShedPolicy,
    /// Label-feedback accuracy window length.
    pub monitor_window: usize,
    /// Close a connection after this much request silence
    /// (`Duration::ZERO` = never).
    pub idle_timeout: Duration,
    /// Longest accepted protocol line in bytes; longer lines answer
    /// `err` and are discarded to the next newline.
    pub max_line_bytes: usize,
    /// Max simultaneously served connections; extras are answered
    /// `err busy` and closed (0 = unlimited).
    pub max_conns: usize,
    /// Per-request deadline: requests queued longer answer
    /// [`ServeError::Deadline`] (`Duration::ZERO` = none).
    pub deadline: Duration,
    /// Largest accepted `push-artifact` payload in bytes; a bigger
    /// header answers `err` and the connection is closed (the client
    /// was about to stream that many bytes).
    pub max_artifact_bytes: usize,
    /// Largest accepted HTTP request body in bytes; bigger
    /// `Content-Length` headers answer `413` (HTTP front end only).
    pub max_body_bytes: usize,
    /// Shared-secret auth token; empty disables auth.  When set, the
    /// line protocol requires an `auth <token>` handshake as each
    /// connection's first command and the HTTP front end requires
    /// `Authorization: Bearer <token>` on every request.
    pub auth_token: String,
}

impl Default for ServeOptions {
    fn default() -> Self {
        Self {
            batch_max: 64,
            queue_max: 256,
            shed: ShedPolicy::Reject,
            monitor_window: 256,
            idle_timeout: Duration::from_secs(300),
            max_line_bytes: 64 * 1024,
            max_conns: 1024,
            deadline: Duration::ZERO,
            max_artifact_bytes: 16 * 1024 * 1024,
            max_body_bytes: 1024 * 1024,
            auth_token: String::new(),
        }
    }
}

/// Connection-policing totals (the degradation half of `stats`).
/// Since the telemetry migration this is a *view* over the
/// [`ServeMetrics`] counters ([`ServeMetrics::proto_stats`]) — the
/// `stats` line and `GET /metrics` read the same atomics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ProtoStats {
    /// Connections closed for idling past `idle_timeout`.
    pub idle_timeouts: u64,
    /// Lines rejected for exceeding `max_line_bytes`.
    pub oversize_lines: u64,
    /// Connections turned away at the `max_conns` cap.
    pub busy_rejected: u64,
}

/// Per-connection read-loop limits (a slice of [`ServeOptions`] so
/// connection threads don't need the whole options struct).
#[derive(Clone)]
struct ConnLimits {
    idle_timeout: Duration,
    max_line_bytes: usize,
    max_artifact_bytes: usize,
    auth_token: String,
}

/// Server-side handler for the fleet verbs (`push-artifact` /
/// `activate` / `rollback` / `fleet-status`), implemented by
/// [`crate::fleet::ReplicaState`].  Methods return the full reply
/// line (`ok ...` / `err ...`): fleet state transitions are never
/// half-reported — whatever the handler did is exactly what the
/// controller reads back.  The engine calls these after draining
/// in-flight requests, so a handler swapping the registry observes
/// the same quiesced-registry guarantee as `swap-model`.
pub trait FleetHandler {
    /// Verify and stage a pushed artifact bundle.
    fn push_artifact(&mut self, registry: &mut ModelRegistry, payload: &str) -> String;
    /// Activate a staged `name@v<version>` bundle into the registry.
    fn activate(&mut self, registry: &mut ModelRegistry, name: &str, version: u64) -> String;
    /// Restore `name`'s last-good generation.
    fn rollback(&mut self, registry: &mut ModelRegistry, name: &str) -> String;
    /// One-line fleet status; `window_accuracy` is the monitor's
    /// feedback-accuracy window (the auto-rollback signal).
    fn fleet_status(&self, registry: &ModelRegistry, window_accuracy: Option<f64>) -> String;
}

/// What a completed [`serve`] run did.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ServeReport {
    pub connections: u64,
    pub engine: EngineStats,
    pub drift: DriftReport,
    pub proto: ProtoStats,
}

/// One line in flight from a connection reader to the engine.  Parse
/// failures travel the same path as commands: the engine answers them
/// in arrival order, so a pipelining client's replies stay aligned
/// with its requests even around malformed lines.  The reply sender is
/// bounded ([`REPLY_BACKLOG`]) and the engine only ever `try_send`s —
/// a stalled client can cost at most a fixed backlog, never engine
/// stalls or unbounded memory.
pub(crate) struct Incoming {
    pub(crate) cmd: Result<Command, ServeError>,
    pub(crate) reply: mpsc::SyncSender<String>,
}

/// What kind of reply a queued batch request expects.
enum ReplyKind {
    Decision,
    Predict,
    Feedback { y: f32 },
}

struct WaitingReply {
    reply: mpsc::SyncSender<String>,
    kind: ReplyKind,
}

/// Run the server on an already-bound listener until a `shutdown`
/// command (binding is the caller's job so tests and the CLI can both
/// pick their own address, including port 0).  Returns the lifetime
/// counters.
///
/// Thread topology: [`Backend`](crate::runtime::Backend)s are
/// deliberately not `Send` (PJRT handles are thread-local), so the
/// engine — the only holder of the registry — runs **on the calling
/// thread**; the accept loop and the per-connection reader/writer
/// pairs are the scoped threads, shipping parsed [`Command`]s in over
/// an mpsc channel and reply lines back out.  The registry never
/// crosses a thread boundary.
pub fn serve(
    listener: TcpListener,
    registry: ModelRegistry,
    opts: &ServeOptions,
) -> Result<ServeReport, ServeError> {
    serve_impl(listener, None, registry, opts, None)
}

/// [`serve`] with the fleet verbs enabled: `handler` (normally a
/// [`crate::fleet::ReplicaState`]) answers `push-artifact` /
/// `activate` / `rollback` / `fleet-status`, running on the engine
/// thread with exclusive access to the registry.
pub fn serve_fleet(
    listener: TcpListener,
    registry: ModelRegistry,
    opts: &ServeOptions,
    handler: &mut dyn FleetHandler,
) -> Result<ServeReport, ServeError> {
    serve_impl(listener, None, registry, opts, Some(handler))
}

/// [`serve`] with an optional HTTP/1.1 front end: connections on
/// `http` speak `POST /predict|/decision` + `GET /metrics|/healthz`
/// (see [`super::http`]) and feed the **same** engine channel as the
/// line protocol, so HTTP-batched answers are bit-identical to
/// line-protocol answers by construction.
pub fn serve_bound(
    listener: TcpListener,
    http: Option<TcpListener>,
    registry: ModelRegistry,
    opts: &ServeOptions,
) -> Result<ServeReport, ServeError> {
    serve_impl(listener, http, registry, opts, None)
}

/// [`serve_bound`] with the fleet verbs enabled (fleet verbs stay
/// line-protocol-only; HTTP carries queries and observability).
pub fn serve_fleet_bound(
    listener: TcpListener,
    http: Option<TcpListener>,
    registry: ModelRegistry,
    opts: &ServeOptions,
    handler: &mut dyn FleetHandler,
) -> Result<ServeReport, ServeError> {
    serve_impl(listener, http, registry, opts, Some(handler))
}

fn serve_impl(
    listener: TcpListener,
    http: Option<TcpListener>,
    registry: ModelRegistry,
    opts: &ServeOptions,
    fleet: Option<&mut dyn FleetHandler>,
) -> Result<ServeReport, ServeError> {
    listener.set_nonblocking(true)?;
    if let Some(hl) = &http {
        hl.set_nonblocking(true)?;
    }
    let stop = AtomicBool::new(false);
    let metrics = ServeMetrics::new();
    let active = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<Incoming>();
    let opts = opts.clone();
    std::thread::scope(|s| {
        let stop = &stop;
        let metrics = &metrics;
        let active = &active;
        let opts_ref = &opts;
        let limits = ConnLimits {
            idle_timeout: opts.idle_timeout,
            max_line_bytes: opts.max_line_bytes,
            max_artifact_bytes: opts.max_artifact_bytes,
            auth_token: opts.auth_token.clone(),
        };
        let max_conns = opts.max_conns;
        let http_acceptor = http.map(|hl| {
            let tx = tx.clone();
            s.spawn(move || http::accept_loop(hl, tx, stop, s, opts_ref, metrics, active))
        });
        let acceptor = s.spawn(move || {
            accept_loop(listener, tx, stop, s, limits, max_conns, metrics, active)
        });
        // The engine owns the (non-Send) registry and runs here; it
        // returns once every channel sender is gone — i.e. after the
        // accept loops and every connection reader have exited.
        let (engine, drift) = engine_loop(registry, opts_ref, rx, metrics, fleet);
        let http_err = match http_acceptor.map(|h| h.join()) {
            None => None,
            Some(Ok(e)) => e,
            Some(Err(_)) => Some(ServeError::Io("http accept thread panicked".into())),
        };
        match acceptor.join() {
            Ok((connections, None)) => match http_err {
                None => {
                    Ok(ServeReport { connections, engine, drift, proto: metrics.proto_stats() })
                }
                Some(e) => Err(e),
            },
            Ok((_, Some(e))) => Err(e),
            Err(_) => Err(ServeError::Io("accept thread panicked".into())),
        }
    })
}

/// Accept until the stop flag rises (polling — the listener is
/// nonblocking so a `shutdown` arriving on one connection stops the
/// whole server within one [`POLL`]).  Returns the connection count
/// and the fatal accept error, if any.
#[allow(clippy::too_many_arguments)] // internal fan-out of serve()'s state
fn accept_loop<'scope, 'env>(
    listener: TcpListener,
    tx: mpsc::Sender<Incoming>,
    stop: &'scope AtomicBool,
    s: &'scope std::thread::Scope<'scope, 'env>,
    limits: ConnLimits,
    max_conns: usize,
    metrics: &'scope ServeMetrics,
    active: &'scope AtomicUsize,
) -> (u64, Option<ServeError>) {
    let mut connections = 0u64;
    loop {
        if stop.load(Ordering::Relaxed) {
            return (connections, None);
        }
        match listener.accept() {
            Ok((mut stream, _peer)) => {
                // Connection cap: refuse with an explicit `err busy`
                // instead of accepting unboundedly (each connection
                // costs two scoped threads + a reply backlog).
                if max_conns > 0 && active.load(Ordering::Relaxed) >= max_conns {
                    metrics.busy_rejected.inc();
                    // best effort: the socket may inherit the
                    // listener's nonblocking flag
                    let _ = stream.set_nonblocking(false);
                    let _ = stream.set_write_timeout(Some(POLL));
                    let _ = stream.write_all(b"err busy: connection limit reached\n");
                    continue; // dropped => closed
                }
                connections += 1;
                metrics.connections.inc();
                active.fetch_add(1, Ordering::Relaxed);
                let tx = tx.clone();
                let limits = limits.clone();
                s.spawn(move || {
                    connection_loop(stream, tx, stop, limits, metrics);
                    active.fetch_sub(1, Ordering::Relaxed);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(POLL);
            }
            Err(e) => {
                stop.store(true, Ordering::Relaxed);
                return (connections, Some(ServeError::from(e)));
            }
        }
    }
}

/// Per-connection reader (this thread) + writer (scoped): the reader
/// parses lines and forwards commands without waiting for answers, so
/// a pipelining client's requests coalesce into engine micro-batches;
/// the writer drains the reply channel in engine-emitted (= request)
/// order.
fn connection_loop(
    stream: TcpStream,
    tx: mpsc::Sender<Incoming>,
    stop: &AtomicBool,
    limits: ConnLimits,
    metrics: &ServeMetrics,
) {
    // Accepted sockets inherit the listener's nonblocking flag on some
    // platforms (Windows); the reader wants blocking reads with a
    // timeout, not a busy-spin.
    if stream.set_nonblocking(false).is_err() || stream.set_read_timeout(Some(POLL)).is_err() {
        return;
    }
    let write_half = match stream.try_clone() {
        Ok(w) => w,
        Err(_) => return,
    };
    let (reply_tx, reply_rx) = mpsc::sync_channel::<String>(REPLY_BACKLOG);
    std::thread::scope(|s| {
        s.spawn(move || {
            let mut w = BufWriter::new(write_half);
            while let Ok(line) = reply_rx.recv() {
                if w.write_all(line.as_bytes())
                    .and_then(|()| w.write_all(b"\n"))
                    .and_then(|()| w.flush())
                    .is_err()
                {
                    break;
                }
            }
        });
        // Raw-byte line reader (`read_until`, not `read_line`): bytes
        // already read always stay appended across read timeouts —
        // `read_line`'s UTF-8 guard would *discard* a valid prefix
        // that a timeout split mid multibyte character — and UTF-8 is
        // validated per complete line, so a non-UTF-8 line answers
        // `err` in order and the connection survives.
        let mut rd = BufReader::new(&stream);
        let mut buf: Vec<u8> = Vec::new();
        let mut last_rx = Instant::now();
        // After an oversized line is answered, swallow the rest of it
        // (up to its newline) without replying again.
        let mut discarding = false;
        // With auth enabled, the connection is untrusted until its
        // first line is a valid `auth <token>` handshake.
        let mut authed = limits.auth_token.is_empty();
        loop {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            // Injection site `proto.read`: a slow or wedged peer path.
            match crate::util::fault::armed(crate::util::fault::site::PROTO_READ) {
                Some(crate::util::fault::FaultKind::Stall(ms)) => {
                    std::thread::sleep(Duration::from_millis(ms));
                }
                Some(crate::util::fault::FaultKind::Io) => break,
                _ => {}
            }
            let before = buf.len();
            match rd.read_until(b'\n', &mut buf) {
                Ok(0) => break, // client closed
                Ok(_) => {
                    last_rx = Instant::now();
                    if discarding {
                        // tail of the already-answered oversized line
                        discarding = false;
                        buf.clear();
                        continue;
                    }
                    if buf.len() > limits.max_line_bytes {
                        metrics.oversize_lines.inc();
                        let e = ServeError::BadRequest(format!(
                            "line exceeds {} bytes",
                            limits.max_line_bytes
                        ));
                        if !authed {
                            // an untrusted peer doesn't get to keep the
                            // connection open after an oversized line
                            let _ = reply_tx.try_send(format!("err {e}"));
                            break;
                        }
                        // through the engine, so the err reply stays in
                        // FIFO position relative to queued requests
                        if tx.send(Incoming { cmd: Err(e), reply: reply_tx.clone() }).is_err() {
                            break;
                        }
                        buf.clear();
                        continue;
                    }
                    // Auth handshake gate: until the first line is a
                    // valid `auth <token>`, nothing reaches the engine.
                    if !authed {
                        let line = match std::str::from_utf8(&buf) {
                            Ok(t) => t.trim().to_string(),
                            // non-UTF-8 is certainly not the handshake
                            Err(_) => "\u{FFFD}".into(),
                        };
                        buf.clear();
                        if line.is_empty() {
                            continue;
                        }
                        let ok = line
                            .strip_prefix("auth ")
                            .map(|tok| tok.trim() == limits.auth_token)
                            .unwrap_or(false);
                        if ok {
                            authed = true;
                            // direct replies are safe pre-auth: nothing
                            // from this connection is in flight yet
                            let _ = reply_tx.try_send("ok authed".into());
                            continue;
                        }
                        metrics.auth_failures.inc();
                        let _ = reply_tx.try_send(format!("err {}", ServeError::Unauthorized));
                        break;
                    }
                    // `push-artifact <len>` switches the reader into
                    // its one length-delimited mode: exactly <len>
                    // payload bytes follow the header (bundles contain
                    // newlines, so line framing can't carry them).
                    let push =
                        std::str::from_utf8(&buf).ok().and_then(|t| parse_push_header(t.trim()));
                    if let Some(header) = push {
                        buf.clear();
                        let want = match header {
                            Ok(n) => n,
                            Err(e) => {
                                if tx
                                    .send(Incoming { cmd: Err(e), reply: reply_tx.clone() })
                                    .is_err()
                                {
                                    break;
                                }
                                continue;
                            }
                        };
                        if want > limits.max_artifact_bytes {
                            let e = ServeError::BadRequest(format!(
                                "artifact exceeds {} bytes",
                                limits.max_artifact_bytes
                            ));
                            let _ = tx.send(Incoming { cmd: Err(e), reply: reply_tx.clone() });
                            // the peer is about to stream `want` bytes
                            // we refuse to buffer: close instead of
                            // misparsing them as protocol lines
                            break;
                        }
                        let mut payload = vec![0u8; want];
                        let mut got = 0usize;
                        let mut alive = true;
                        while got < want {
                            if stop.load(Ordering::Relaxed) {
                                alive = false;
                                break;
                            }
                            match rd.read(&mut payload[got..]) {
                                // EOF mid-payload (torn push): stage
                                // nothing, drop the connection
                                Ok(0) => {
                                    alive = false;
                                    break;
                                }
                                Ok(n) => {
                                    got += n;
                                    last_rx = Instant::now();
                                }
                                Err(e)
                                    if e.kind() == std::io::ErrorKind::WouldBlock
                                        || e.kind() == std::io::ErrorKind::TimedOut
                                        || e.kind() == std::io::ErrorKind::Interrupted =>
                                {
                                    if !limits.idle_timeout.is_zero()
                                        && last_rx.elapsed() >= limits.idle_timeout
                                    {
                                        metrics.idle_timeouts.inc();
                                        alive = false;
                                        break;
                                    }
                                }
                                Err(_) => {
                                    alive = false;
                                    break;
                                }
                            }
                        }
                        if !alive {
                            break;
                        }
                        let cmd = String::from_utf8(payload)
                            .map(|payload| Command::PushArtifact { payload })
                            .map_err(|_| {
                                ServeError::BadRequest(
                                    "artifact payload is not valid UTF-8".into(),
                                )
                            });
                        if tx.send(Incoming { cmd, reply: reply_tx.clone() }).is_err() {
                            break;
                        }
                        continue;
                    }
                    let cmd = match std::str::from_utf8(&buf) {
                        Ok(text) => {
                            let line = text.trim();
                            if line.is_empty() {
                                buf.clear();
                                continue;
                            }
                            parse_line(line)
                        }
                        Err(_) => {
                            Err(ServeError::BadRequest("line is not valid UTF-8".into()))
                        }
                    };
                    let is_shutdown = matches!(cmd, Ok(Command::Shutdown));
                    if tx.send(Incoming { cmd, reply: reply_tx.clone() }).is_err() {
                        break;
                    }
                    if is_shutdown {
                        stop.store(true, Ordering::Relaxed);
                        break;
                    }
                    buf.clear();
                }
                // timeout: re-check the stop flag; the partial line
                // stays in `buf` and completes next round
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut
                        || e.kind() == std::io::ErrorKind::Interrupted =>
                {
                    // a trickling writer made progress without reaching
                    // a newline: alive, just slow — not idle
                    if buf.len() > before {
                        last_rx = Instant::now();
                    }
                    // a mid-line buffer past the cap is answered (and
                    // then discarded) *now*; waiting for its newline
                    // would let one line grow server memory unboundedly
                    if !discarding && buf.len() > limits.max_line_bytes {
                        metrics.oversize_lines.inc();
                        let e = ServeError::BadRequest(format!(
                            "line exceeds {} bytes",
                            limits.max_line_bytes
                        ));
                        if !authed {
                            let _ = reply_tx.try_send(format!("err {e}"));
                            break;
                        }
                        if tx.send(Incoming { cmd: Err(e), reply: reply_tx.clone() }).is_err() {
                            break;
                        }
                        discarding = true;
                        buf.clear();
                    }
                    if !limits.idle_timeout.is_zero()
                        && last_rx.elapsed() >= limits.idle_timeout
                    {
                        metrics.idle_timeouts.inc();
                        // direct reply is safe: an idle connection has
                        // no replies in flight (the engine drains after
                        // every burst)
                        let _ = reply_tx.try_send("err idle timeout, closing connection".into());
                        break;
                    }
                    continue;
                }
                Err(_) => break,
            }
        }
        // Dropping our reply sender (and the engine finishing any
        // in-flight replies) closes the writer's channel.
        drop(reply_tx);
        drop(tx);
    });
}

/// The single engine thread: drains command bursts in arrival order,
/// micro-batching query commands and flushing before any control
/// command replies (per-connection FIFO by construction).
fn engine_loop(
    mut registry: ModelRegistry,
    opts: &ServeOptions,
    rx: mpsc::Receiver<Incoming>,
    metrics: &ServeMetrics,
    mut fleet: Option<&mut dyn FleetHandler>,
) -> (EngineStats, DriftReport) {
    let mut engine = BatchEngine::new(opts.batch_max, opts.queue_max, opts.shed);
    engine.set_deadline(opts.deadline);
    let mut monitor = Monitor::new(opts.monitor_window);
    let mut waiting: BTreeMap<u64, WaitingReply> = BTreeMap::new();
    while let Ok(first) = rx.recv() {
        // Coalesce everything that arrived while we were busy: this is
        // the micro-batch.  An idle server answers batches of 1; a
        // loaded one grows batches up to queue_max and sheds beyond.
        let mut burst = vec![first];
        while let Ok(more) = rx.try_recv() {
            burst.push(more);
        }
        for inc in burst {
            let cmd = match inc.cmd {
                Ok(cmd) => cmd,
                Err(e) => {
                    // A malformed line still consumes a reply slot in
                    // arrival order: flush what precedes it, then err.
                    drain(&mut engine, &mut registry, &mut waiting, &mut monitor);
                    let _ = inc.reply.try_send(format!("err {e}"));
                    continue;
                }
            };
            match cmd {
                Command::Decision { key, x } => {
                    let kind = ReplyKind::Decision;
                    enqueue(&mut engine, &registry, &mut waiting, inc.reply, key, x, kind);
                }
                Command::Predict { key, x } => {
                    let kind = ReplyKind::Predict;
                    enqueue(&mut engine, &registry, &mut waiting, inc.reply, key, x, kind);
                }
                Command::Feedback { key, y, x } => {
                    let kind = ReplyKind::Feedback { y };
                    enqueue(&mut engine, &registry, &mut waiting, inc.reply, key, x, kind);
                }
                Command::Stats => {
                    drain(&mut engine, &mut registry, &mut waiting, &mut monitor);
                    sync_degradation(&mut monitor, &engine, metrics);
                    let _ = inc.reply.try_send(stats_line(&engine, &registry, &monitor));
                }
                Command::SwapModel { name, path } => {
                    // Drain first: in-flight requests were routed (and
                    // version-stamped) against the old model.
                    drain(&mut engine, &mut registry, &mut waiting, &mut monitor);
                    let msg = match SvmModel::load(Path::new(&path)) {
                        Ok(m) => match registry.swap(&name, m) {
                            Ok(v) => format!("ok {name}@v{v}"),
                            Err(e) => format!("err {e}"),
                        },
                        Err(e) => format!("err swap-model: {e:#}"),
                    };
                    let _ = inc.reply.try_send(msg);
                }
                Command::PushArtifact { payload } => {
                    // Staging never touches the registry, but drain
                    // anyway: fleet verbs share swap-model's FIFO
                    // position guarantee.
                    drain(&mut engine, &mut registry, &mut waiting, &mut monitor);
                    let msg = match fleet.as_deref_mut() {
                        Some(h) => h.push_artifact(&mut registry, &payload),
                        None => "err fleet verbs not enabled on this server".into(),
                    };
                    let _ = inc.reply.try_send(msg);
                }
                Command::Activate { name, version } => {
                    drain(&mut engine, &mut registry, &mut waiting, &mut monitor);
                    let msg = match fleet.as_deref_mut() {
                        Some(h) => h.activate(&mut registry, &name, version),
                        None => "err fleet verbs not enabled on this server".into(),
                    };
                    let _ = inc.reply.try_send(msg);
                }
                Command::Rollback { name } => {
                    drain(&mut engine, &mut registry, &mut waiting, &mut monitor);
                    let msg = match fleet.as_deref_mut() {
                        Some(h) => h.rollback(&mut registry, &name),
                        None => "err fleet verbs not enabled on this server".into(),
                    };
                    let _ = inc.reply.try_send(msg);
                }
                Command::FleetStatus => {
                    drain(&mut engine, &mut registry, &mut waiting, &mut monitor);
                    let msg = match fleet.as_deref_mut() {
                        Some(h) => {
                            h.fleet_status(&registry, monitor.report().window_accuracy)
                        }
                        None => "err fleet verbs not enabled on this server".into(),
                    };
                    let _ = inc.reply.try_send(msg);
                }
                Command::Shutdown => {
                    drain(&mut engine, &mut registry, &mut waiting, &mut monitor);
                    let _ = inc.reply.try_send("ok bye".into());
                }
            }
        }
        drain(&mut engine, &mut registry, &mut waiting, &mut monitor);
        // Republish the engine/drift mirrors after every burst, so a
        // `/metrics` scrape is at most one burst stale.
        metrics.publish_engine(&engine.stats(), engine.queued());
        metrics.publish_drift(&monitor.report());
    }
    sync_degradation(&mut monitor, &engine, metrics);
    metrics.publish_engine(&engine.stats(), engine.queued());
    metrics.publish_drift(&monitor.report());
    (engine.stats(), monitor.report())
}

/// Copy the latest shed/expired/policing totals into the monitor so
/// one [`DriftReport`] carries both drift and degradation.
fn sync_degradation(monitor: &mut Monitor, engine: &BatchEngine, metrics: &ServeMetrics) {
    let p = metrics.proto_stats();
    let s = engine.stats();
    monitor.set_degradation(DegradeTotals {
        shed: s.shed,
        expired: s.expired,
        idle_timeouts: p.idle_timeouts,
        oversize_lines: p.oversize_lines,
        busy_rejected: p.busy_rejected,
    });
}

fn enqueue(
    engine: &mut BatchEngine,
    registry: &ModelRegistry,
    waiting: &mut BTreeMap<u64, WaitingReply>,
    reply: mpsc::SyncSender<String>,
    key: Option<String>,
    x: Vec<f32>,
    kind: ReplyKind,
) {
    let id = match engine.submit(registry, key.as_deref(), x) {
        Ok(id) => id,
        // failed submits keep their reply slot: park the error under a
        // fresh request id so flush delivers it in submission order
        Err(e) => engine.park_error(e),
    };
    waiting.insert(id, WaitingReply { reply, kind });
}

/// Flush the engine and deliver every resolved request's reply (in
/// request-id order — [`BatchEngine::flush`] sorts).
fn drain(
    engine: &mut BatchEngine,
    registry: &mut ModelRegistry,
    waiting: &mut BTreeMap<u64, WaitingReply>,
    monitor: &mut Monitor,
) {
    for (id, res) in engine.flush(registry) {
        let Some(w) = waiting.remove(&id) else { continue };
        let line = match res {
            Ok(d) => {
                monitor.record(d.value);
                match w.kind {
                    ReplyKind::Decision => format!("ok {} {}@v{}", d.value, d.model, d.version),
                    ReplyKind::Predict => {
                        let label = if d.value >= 0.0 { "+1" } else { "-1" };
                        format!("ok {label} {} {}@v{}", d.value, d.model, d.version)
                    }
                    ReplyKind::Feedback { y } => {
                        let n_svs = registry.n_svs_of(&d.model).unwrap_or(0);
                        let hit = monitor.feedback(d.value, y, n_svs);
                        format!(
                            "ok {} {} {}@v{}",
                            if hit { "hit" } else { "miss" },
                            d.value,
                            d.model,
                            d.version
                        )
                    }
                }
            }
            Err(e) => format!("err {e}"),
        };
        let _ = w.reply.try_send(line);
    }
}

fn stats_line(engine: &BatchEngine, registry: &ModelRegistry, monitor: &Monitor) -> String {
    let s = engine.stats();
    let r = monitor.report();
    let mean_batch = if s.batches > 0 { s.rows as f64 / s.batches as f64 } else { 0.0 };
    let acc = match r.window_accuracy {
        Some(a) => format!("{a:.4}"),
        None => "na".into(),
    };
    let models: Vec<String> = registry
        .status()
        .iter()
        .map(|m| format!("{}@v{}:{}sv", m.name, m.version, m.n_svs))
        .collect();
    format!(
        "ok served={} shed={} queued={} batches={} mean_batch={mean_batch:.2} \
         low_margin={:.4} mean_margin={:.4} window_acc={acc} feedback={} \
         expired={} idle_timeout={} oversize={} busy={} models={}",
        s.served,
        s.shed,
        engine.queued(),
        s.batches,
        r.low_margin_fraction,
        r.mean_abs_margin,
        r.feedback_seen,
        r.degrade.expired,
        r.degrade.idle_timeouts,
        r.degrade.oversize_lines,
        r.degrade.busy_rejected,
        models.join(",")
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_covers_the_grammar() {
        assert_eq!(
            parse_line("predict 0.5 -1.25 3").unwrap(),
            Command::Predict { key: None, x: vec![0.5, -1.25, 3.0] }
        );
        assert_eq!(
            parse_line("decision key=user-7 1 2").unwrap(),
            Command::Decision { key: Some("user-7".into()), x: vec![1.0, 2.0] }
        );
        assert_eq!(
            parse_line("feedback key=u -1 0.25 0.5").unwrap(),
            Command::Feedback { key: Some("u".into()), y: -1.0, x: vec![0.25, 0.5] }
        );
        assert_eq!(
            parse_line("feedback +1 2").unwrap(),
            Command::Feedback { key: None, y: 1.0, x: vec![2.0] }
        );
        assert_eq!(parse_line("stats").unwrap(), Command::Stats);
        assert_eq!(
            parse_line("swap-model champ /tmp/m.txt").unwrap(),
            Command::SwapModel { name: "champ".into(), path: "/tmp/m.txt".into() }
        );
        assert_eq!(parse_line("shutdown").unwrap(), Command::Shutdown);
        // surrounding whitespace is the reader's problem; tokens split
        assert_eq!(
            parse_line("  predict   1.0  ").unwrap(),
            Command::Predict { key: None, x: vec![1.0] }
        );
    }

    #[test]
    fn parse_rejects_malformed_lines_typed() {
        for bad in [
            "",
            "bogus 1 2",
            "predict",
            "predict key=u",
            "predict 1 nan-ish",
            "predict inf",
            "feedback 0 1 2",
            "feedback",
            "stats now",
            "swap-model onlyname",
            "swap-model a b c",
        ] {
            match parse_line(bad) {
                Err(ServeError::BadRequest(_)) => {}
                other => panic!("{bad:?}: expected BadRequest, got {other:?}"),
            }
        }
        // "1" doubles as the +1 label shorthand: one feature follows
        assert_eq!(
            parse_line("feedback 1 2").unwrap(),
            Command::Feedback { key: None, y: 1.0, x: vec![2.0] }
        );
    }

    #[test]
    fn non_finite_features_rejected() {
        assert!(matches!(parse_line("predict inf 1"), Err(ServeError::BadRequest(_))));
        assert!(matches!(parse_line("predict NaN"), Err(ServeError::BadRequest(_))));
    }

    #[test]
    fn parse_covers_the_fleet_verbs() {
        assert_eq!(
            parse_line("activate champ@v3").unwrap(),
            Command::Activate { name: "champ".into(), version: 3 }
        );
        // the 'v' is optional sugar
        assert_eq!(
            parse_line("activate champ@3").unwrap(),
            Command::Activate { name: "champ".into(), version: 3 }
        );
        assert_eq!(
            parse_line("rollback champ").unwrap(),
            Command::Rollback { name: "champ".into() }
        );
        assert_eq!(parse_line("fleet-status").unwrap(), Command::FleetStatus);
        for bad in [
            "activate",
            "activate champ",
            "activate champ@vX",
            "activate @v3",
            "activate a@v1 extra",
            "rollback",
            "rollback a b",
            "fleet-status now",
            // reader-handled: reaching the parser means it was misused
            "push-artifact 128",
        ] {
            match parse_line(bad) {
                Err(ServeError::BadRequest(_)) => {}
                other => panic!("{bad:?}: expected BadRequest, got {other:?}"),
            }
        }
    }

    #[test]
    fn push_header_parses_lengths() {
        assert_eq!(parse_push_header("push-artifact 128"), Some(Ok(128)));
        assert_eq!(parse_push_header("predict 1 2"), None);
        assert!(matches!(parse_push_header("push-artifact"), Some(Err(_))));
        assert!(matches!(parse_push_header("push-artifact 0"), Some(Err(_))));
        assert!(matches!(parse_push_header("push-artifact twelve"), Some(Err(_))));
        assert!(matches!(parse_push_header("push-artifact 12 34"), Some(Err(_))));
    }
}

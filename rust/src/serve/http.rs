//! HTTP/1.1 front end over the serving engine.
//!
//! A second listener, same engine: every connection accepted here
//! parses `HTTP/1.1` requests with `Content-Length` framing and feeds
//! the **same** [`Incoming`] channel as the line protocol, so an
//! HTTP-batched answer is bit-identical to a line-protocol answer by
//! construction — there is exactly one parse path, one batch engine,
//! and one reply formatter.  Routes:
//!
//! * `POST /predict`, `POST /decision` — the body carries one request
//!   per line in line-protocol *argument* form (`[key=<k>] <f1> <f2>
//!   ...`, no verb; the path is the verb).  The response body carries
//!   one reply line per request line, in order.  A single-line request
//!   maps its `err` reply onto a typed status (see
//!   [`status_for_reply`]); multi-line bodies always answer `200` and
//!   report per-line outcomes in the body, exactly as a pipelining
//!   line-protocol client would see them.
//! * `GET /metrics` — the [`crate::telemetry::Registry`] exposition
//!   text (see telemetry module docs for the format).
//! * `GET /healthz` — `200 ok` while the engine is accepting.
//!
//! Degradation mirrors the line protocol: request heads are capped at
//! `max_line_bytes` (431), bodies at `max_body_bytes` (413, enforced
//! at header-parse time before any body byte is buffered), connections
//! share the line protocol's `max_conns` budget (503 at accept), and
//! `idle_timeout` closes silent connections (408 when a request is
//! half-read).  With `auth_token` set, every request must carry
//! `Authorization: Bearer <token>` (401 + close otherwise).  Keep-alive
//! is honored per HTTP/1.1 defaults (`Connection: close` / HTTP/1.0
//! opt-outs respected); every degradation answers a well-formed
//! response before the connection drops.
//!
//! The request *parser* ([`parse_request_head`] /
//! [`validate_request_text`]) is a pure function over text, fuzzed by
//! `tests/fuzz_replay.rs` over `fuzz/corpus/http/`: malformed input
//! must map to a typed [`HttpError`], never a panic.

use super::metrics::ServeMetrics;
use super::proto::{parse_line, Incoming, ServeOptions, POLL, REPLY_BACKLOG};
use crate::error::ServeError;
use std::fmt;
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// Longest a request waits for the engine to answer all its lines
/// before the connection gives up with `503` (the engine is wedged or
/// the reply backlog overflowed — either way the connection is
/// desynced and closes).
const ENGINE_WAIT: Duration = Duration::from_secs(30);

/// Hard cap on header lines per request head (431 beyond it).
const MAX_HEADERS: usize = 64;

// ---------------------------------------------------------------------------
// pure request parsing (fuzzed surface)
// ---------------------------------------------------------------------------

/// The two methods the front end routes; anything else is `405`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Get,
    Post,
}

/// A parsed request head (everything before the blank line).
#[derive(Clone, Debug, PartialEq)]
pub struct RequestHead {
    pub method: Method,
    pub path: String,
    /// `Content-Length` if present (already bounded by
    /// `max_body_bytes` — an oversized declaration is a parse error).
    pub content_length: Option<usize>,
    /// The `Authorization: Bearer <token>` credential, if any.
    pub bearer: Option<String>,
    /// Whether the connection persists after this exchange
    /// (HTTP/1.1 default yes, HTTP/1.0 default no, `Connection:`
    /// header overrides either way).
    pub keep_alive: bool,
}

/// A typed request rejection: the status line to answer and the
/// human-readable reason carried in the response body.
#[derive(Clone, Debug, PartialEq)]
pub struct HttpError {
    pub status: u16,
    pub reason: String,
}

impl HttpError {
    fn new(status: u16, reason: impl Into<String>) -> Self {
        Self { status, reason: reason.into() }
    }
}

impl fmt::Display for HttpError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{} {}: {}", self.status, reason_phrase(self.status), self.reason)
    }
}

/// The standard reason phrase for every status the front end emits.
pub fn reason_phrase(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        401 => "Unauthorized",
        404 => "Not Found",
        405 => "Method Not Allowed",
        408 => "Request Timeout",
        411 => "Length Required",
        413 => "Payload Too Large",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        505 => "HTTP Version Not Supported",
        _ => "Error",
    }
}

/// Find the end of the request head in a raw byte buffer: the index
/// one past the `\r\n\r\n` (or bare `\n\n`) terminator, or `None`
/// while the head is still arriving.
fn find_head_end(buf: &[u8]) -> Option<usize> {
    let mut i = 0;
    while i + 1 < buf.len() {
        if buf[i] == b'\n' {
            if buf[i + 1] == b'\n' {
                return Some(i + 2);
            }
            if i + 2 < buf.len() && buf[i + 1] == b'\r' && buf[i + 2] == b'\n' {
                return Some(i + 3);
            }
        }
        i += 1;
    }
    None
}

/// Parse a request head (request line + headers, already terminated).
/// Pure: every malformation maps to a typed [`HttpError`] and nothing
/// panics on arbitrary input.  `max_body_bytes` bounds the accepted
/// `Content-Length` declaration so the connection can refuse a body
/// before buffering a single byte of it.
pub fn parse_request_head(text: &str, max_body_bytes: usize) -> Result<RequestHead, HttpError> {
    let mut it = text.lines();
    // Tolerate empty line(s) before the request line (RFC 9112 §2.2).
    let request_line = loop {
        match it.next() {
            Some(l) if l.trim().is_empty() => continue,
            Some(l) => break l,
            None => return Err(HttpError::new(400, "empty request")),
        }
    };
    let toks: Vec<&str> = request_line.split_ascii_whitespace().collect();
    if toks.len() != 3 {
        return Err(HttpError::new(400, "request line must be METHOD PATH VERSION"));
    }
    let method = match toks[0] {
        "GET" => Method::Get,
        "POST" => Method::Post,
        m => return Err(HttpError::new(405, format!("method {m:?} not allowed"))),
    };
    if !toks[1].starts_with('/') {
        return Err(HttpError::new(400, format!("path {:?} must start with '/'", toks[1])));
    }
    let mut keep_alive = match toks[2] {
        "HTTP/1.1" => true,
        "HTTP/1.0" => false,
        v => return Err(HttpError::new(505, format!("unsupported version {v:?}"))),
    };
    let mut content_length = None;
    let mut bearer = None;
    let mut count = 0usize;
    for line in it {
        if line.trim().is_empty() {
            break;
        }
        count += 1;
        if count > MAX_HEADERS {
            return Err(HttpError::new(431, format!("more than {MAX_HEADERS} header lines")));
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(HttpError::new(400, format!("malformed header line {line:?}")));
        };
        let value = value.trim();
        match name.trim().to_ascii_lowercase().as_str() {
            "content-length" => {
                let n: usize = value
                    .parse()
                    .map_err(|_| HttpError::new(400, format!("bad content-length {value:?}")))?;
                if n > max_body_bytes {
                    return Err(HttpError::new(
                        413,
                        format!("declared body of {n} bytes exceeds the {max_body_bytes} limit"),
                    ));
                }
                content_length = Some(n);
            }
            "authorization" => {
                bearer = value.strip_prefix("Bearer ").map(|t| t.trim().to_string());
            }
            "connection" => {
                if value.eq_ignore_ascii_case("close") {
                    keep_alive = false;
                } else if value.eq_ignore_ascii_case("keep-alive") {
                    keep_alive = true;
                }
            }
            _ => {}
        }
    }
    match method {
        Method::Post if content_length.is_none() => {
            Err(HttpError::new(411, "POST requires a content-length header"))
        }
        Method::Get if content_length.unwrap_or(0) > 0 => {
            Err(HttpError::new(400, "GET must not carry a body"))
        }
        _ => Ok(RequestHead {
            method,
            path: toks[1].to_string(),
            content_length,
            bearer,
            keep_alive,
        }),
    }
}

/// Validate one whole request (head + body) as the fuzz harness sees
/// it: head terminator present, head parses, the body actually carries
/// `Content-Length` bytes, and a POST body is valid UTF-8 (the live
/// reader slices the body out of a raw byte stream at the declared
/// length, which can land mid multibyte character — that must be a
/// `400`, never a panic).
pub fn validate_request_text(text: &str, max_body_bytes: usize) -> Result<RequestHead, HttpError> {
    let bytes = text.as_bytes();
    let head_len =
        find_head_end(bytes).ok_or_else(|| HttpError::new(400, "truncated request head"))?;
    // `bytes[..head_len]` is a slice of a `&str` ending right after a
    // `\n`, so it is always valid UTF-8.
    let head_text = std::str::from_utf8(&bytes[..head_len])
        .map_err(|_| HttpError::new(400, "request head is not valid utf-8"))?;
    let head = parse_request_head(head_text, max_body_bytes)?;
    let want = head.content_length.unwrap_or(0);
    let body = bytes
        .get(head_len..head_len.saturating_add(want))
        .ok_or_else(|| HttpError::new(400, "body shorter than content-length"))?;
    if head.method == Method::Post && std::str::from_utf8(body).is_err() {
        return Err(HttpError::new(400, "body is not valid utf-8"));
    }
    Ok(head)
}

/// Map a single engine reply line onto a response status: `ok` is
/// `200`; `err` sniffs the typed [`ServeError`] rendering the engine
/// used (`queue full` / `request shed` → 503, `deadline exceeded` →
/// 504, `unknown model` → 404, `io:` → 500, anything else → 400).
pub fn status_for_reply(reply: &str) -> u16 {
    let Some(msg) = reply.strip_prefix("err ") else {
        return 200;
    };
    if msg.starts_with("queue full") || msg.starts_with("request shed") {
        503
    } else if msg.starts_with("deadline exceeded") {
        504
    } else if msg.starts_with("unknown model") {
        404
    } else if msg.starts_with("io:") {
        500
    } else {
        400
    }
}

// ---------------------------------------------------------------------------
// connection handling
// ---------------------------------------------------------------------------

/// What one read attempt against the socket produced.
enum ReadOutcome {
    /// Bytes were appended to the buffer.
    Data,
    /// Orderly close from the peer.
    Eof,
    /// The [`POLL`] read timeout elapsed with nothing to read.
    TimedOut,
    /// A hard socket error.
    Failed,
}

fn read_some(rd: &mut BufReader<&TcpStream>, buf: &mut Vec<u8>) -> ReadOutcome {
    let mut chunk = [0u8; 4096];
    match rd.read(&mut chunk) {
        Ok(0) => ReadOutcome::Eof,
        Ok(n) => {
            buf.extend_from_slice(&chunk[..n]);
            ReadOutcome::Data
        }
        Err(e)
            if e.kind() == std::io::ErrorKind::WouldBlock
                || e.kind() == std::io::ErrorKind::TimedOut =>
        {
            ReadOutcome::TimedOut
        }
        Err(_) => ReadOutcome::Failed,
    }
}

/// Write one framed response.  Returns `false` on a dead socket.
fn respond(w: &mut BufWriter<TcpStream>, status: u16, body: &str, close: bool) -> bool {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: text/plain; charset=utf-8\r\nContent-Length: {}\r\n{}\r\n",
        status,
        reason_phrase(status),
        body.len(),
        if close { "Connection: close\r\n" } else { "" }
    );
    w.write_all(head.as_bytes())
        .and_then(|()| w.write_all(body.as_bytes()))
        .and_then(|()| w.flush())
        .is_ok()
}

/// Accept HTTP connections until the stop flag rises.  Same polling
/// accept idiom as the line protocol's loop, same shared `active`
/// connection budget (`max_conns` caps line + HTTP together), same
/// fatal-error contract: a non-`WouldBlock` accept failure raises the
/// stop flag and is returned for [`super::proto::serve_bound`] to
/// propagate.
pub(crate) fn accept_loop<'scope, 'env>(
    listener: TcpListener,
    tx: mpsc::Sender<Incoming>,
    stop: &'scope AtomicBool,
    s: &'scope std::thread::Scope<'scope, 'env>,
    opts: &'scope ServeOptions,
    metrics: &'scope ServeMetrics,
    active: &'scope AtomicUsize,
) -> Option<ServeError> {
    loop {
        if stop.load(Ordering::Relaxed) {
            return None;
        }
        match listener.accept() {
            Ok((mut stream, _peer)) => {
                if opts.max_conns > 0 && active.load(Ordering::Relaxed) >= opts.max_conns {
                    metrics.http_busy.inc();
                    let _ = stream.set_nonblocking(false);
                    let _ = stream.set_write_timeout(Some(POLL));
                    let body = "busy: connection limit reached\n";
                    let _ = stream.write_all(
                        format!(
                            "HTTP/1.1 503 Service Unavailable\r\n\
                             Content-Type: text/plain; charset=utf-8\r\n\
                             Content-Length: {}\r\nConnection: close\r\n\r\n{}",
                            body.len(),
                            body
                        )
                        .as_bytes(),
                    );
                    continue; // dropped => closed
                }
                metrics.http_connections.inc();
                active.fetch_add(1, Ordering::Relaxed);
                let tx = tx.clone();
                s.spawn(move || {
                    connection_loop(stream, tx, stop, opts, metrics);
                    active.fetch_sub(1, Ordering::Relaxed);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(POLL);
            }
            Err(e) => {
                stop.store(true, Ordering::Relaxed);
                return Some(ServeError::from(e));
            }
        }
    }
}

/// One HTTP connection: read a head, police it, read the body,
/// dispatch, respond, repeat while keep-alive holds.  Requests on a
/// connection are strictly sequential, so the per-connection reply
/// channel stays FIFO-aligned with the lines this request submitted.
fn connection_loop(
    stream: TcpStream,
    tx: mpsc::Sender<Incoming>,
    stop: &AtomicBool,
    opts: &ServeOptions,
    metrics: &ServeMetrics,
) {
    if stream.set_nonblocking(false).is_err() || stream.set_read_timeout(Some(POLL)).is_err() {
        return;
    }
    let mut w = match stream.try_clone() {
        Ok(half) => BufWriter::new(half),
        Err(_) => return,
    };
    let (reply_tx, reply_rx) = mpsc::sync_channel::<String>(REPLY_BACKLOG);
    let mut rd = BufReader::new(&stream);
    let mut buf: Vec<u8> = Vec::new();
    let mut last_rx = Instant::now();
    'conn: loop {
        // -- phase 1: accumulate a complete request head ----------------
        let head_len = loop {
            if let Some(n) = find_head_end(&buf) {
                break n;
            }
            if stop.load(Ordering::Relaxed) {
                break 'conn;
            }
            if buf.len() > opts.max_line_bytes {
                metrics.http_oversize.inc();
                let _ = respond(
                    &mut w,
                    431,
                    &format!("request head exceeds {} bytes\n", opts.max_line_bytes),
                    true,
                );
                metrics.http_response(431);
                break 'conn;
            }
            // Injection site `http.read`: a slow or wedged peer path.
            match crate::util::fault::armed(crate::util::fault::site::HTTP_READ) {
                Some(crate::util::fault::FaultKind::Stall(ms)) => {
                    std::thread::sleep(Duration::from_millis(ms));
                }
                Some(crate::util::fault::FaultKind::Io) => {
                    metrics.http_read_errors.inc();
                    break 'conn;
                }
                _ => {}
            }
            match read_some(&mut rd, &mut buf) {
                ReadOutcome::Data => last_rx = Instant::now(),
                ReadOutcome::Eof => break 'conn,
                ReadOutcome::Failed => {
                    metrics.http_read_errors.inc();
                    break 'conn;
                }
                ReadOutcome::TimedOut => {
                    if opts.idle_timeout != Duration::ZERO
                        && last_rx.elapsed() >= opts.idle_timeout
                    {
                        metrics.http_idle_timeouts.inc();
                        if !buf.is_empty() {
                            // a half-sent request earns an answer; a
                            // silent keep-alive just closes
                            let _ = respond(&mut w, 408, "idle timeout\n", true);
                            metrics.http_response(408);
                        }
                        break 'conn;
                    }
                }
            }
        };
        metrics.http_requests.inc();
        let started = Instant::now();
        // -- phase 2: parse + police the head ---------------------------
        let head_text = String::from_utf8_lossy(&buf[..head_len]).into_owned();
        let head = match parse_request_head(&head_text, opts.max_body_bytes) {
            Ok(h) => h,
            Err(e) => {
                if e.status == 413 {
                    metrics.http_oversize.inc();
                }
                // framing is unknown past a bad head: answer and close
                let _ = respond(&mut w, e.status, &format!("{}\n", e.reason), true);
                metrics.http_response(e.status);
                metrics.http_request_ns.observe_duration(started.elapsed());
                break 'conn;
            }
        };
        if !opts.auth_token.is_empty() && head.bearer.as_deref() != Some(opts.auth_token.as_str())
        {
            metrics.auth_failures.inc();
            let _ = respond(&mut w, 401, &format!("{}\n", ServeError::Unauthorized), true);
            metrics.http_response(401);
            metrics.http_request_ns.observe_duration(started.elapsed());
            break 'conn;
        }
        // -- phase 3: accumulate the declared body ----------------------
        let want = head.content_length.unwrap_or(0);
        while buf.len() < head_len + want {
            if stop.load(Ordering::Relaxed) {
                break 'conn;
            }
            match crate::util::fault::armed(crate::util::fault::site::HTTP_READ) {
                Some(crate::util::fault::FaultKind::Stall(ms)) => {
                    std::thread::sleep(Duration::from_millis(ms));
                }
                Some(crate::util::fault::FaultKind::Io) => {
                    metrics.http_read_errors.inc();
                    break 'conn;
                }
                _ => {}
            }
            match read_some(&mut rd, &mut buf) {
                ReadOutcome::Data => last_rx = Instant::now(),
                ReadOutcome::Eof => break 'conn,
                ReadOutcome::Failed => {
                    metrics.http_read_errors.inc();
                    break 'conn;
                }
                ReadOutcome::TimedOut => {
                    if opts.idle_timeout != Duration::ZERO
                        && last_rx.elapsed() >= opts.idle_timeout
                    {
                        metrics.http_idle_timeouts.inc();
                        let _ = respond(&mut w, 408, "idle timeout\n", true);
                        metrics.http_response(408);
                        break 'conn;
                    }
                }
            }
        }
        // -- phase 4: dispatch ------------------------------------------
        let mut close_after = !head.keep_alive;
        let (status, body) = match std::str::from_utf8(&buf[head_len..head_len + want]) {
            // the declared length can slice mid multibyte character
            Err(_) => (400, "body is not valid utf-8\n".to_string()),
            Ok(body_str) => dispatch(
                &head,
                body_str,
                &tx,
                &reply_tx,
                &reply_rx,
                stop,
                metrics,
                &mut close_after,
            ),
        };
        let wrote = respond(&mut w, status, &body, close_after);
        metrics.http_response(status);
        metrics.http_request_ns.observe_duration(started.elapsed());
        if !wrote || close_after {
            break 'conn;
        }
        buf.drain(..head_len + want);
        last_rx = Instant::now();
    }
}

/// Route one parsed, authenticated request and produce `(status,
/// body)`.  Sets `close_after` when the connection is desynced (engine
/// gone, or replies timed out and stale ones could arrive later).
#[allow(clippy::too_many_arguments)] // internal fan-out of connection state
fn dispatch(
    head: &RequestHead,
    body: &str,
    tx: &mpsc::Sender<Incoming>,
    reply_tx: &mpsc::SyncSender<String>,
    reply_rx: &mpsc::Receiver<String>,
    stop: &AtomicBool,
    metrics: &ServeMetrics,
    close_after: &mut bool,
) -> (u16, String) {
    match (head.method, head.path.as_str()) {
        (Method::Get, "/healthz") => (200, "ok\n".into()),
        (Method::Get, "/metrics") => (200, metrics.registry.render()),
        (Method::Post, "/predict") | (Method::Post, "/decision") => {
            let verb = &head.path[1..];
            let lines: Vec<&str> =
                body.lines().map(str::trim).filter(|l| !l.is_empty()).collect();
            if lines.is_empty() {
                return (400, format!("empty body: expected one {verb} request per line\n"));
            }
            if lines.len() > REPLY_BACKLOG {
                return (
                    400,
                    format!(
                        "too many lines: {} exceeds the {} per-request cap\n",
                        lines.len(),
                        REPLY_BACKLOG
                    ),
                );
            }
            let mut sent = 0usize;
            let mut engine_gone = false;
            for line in &lines {
                let cmd = parse_line(&format!("{verb} {line}"));
                if tx.send(Incoming { cmd, reply: reply_tx.clone() }).is_err() {
                    engine_gone = true;
                    break;
                }
                sent += 1;
            }
            let mut replies = Vec::with_capacity(sent);
            let deadline = Instant::now() + ENGINE_WAIT;
            while replies.len() < sent {
                match reply_rx.recv_timeout(POLL) {
                    Ok(r) => replies.push(r),
                    Err(mpsc::RecvTimeoutError::Timeout) => {
                        if Instant::now() >= deadline || stop.load(Ordering::Relaxed) {
                            break;
                        }
                    }
                    Err(mpsc::RecvTimeoutError::Disconnected) => break,
                }
            }
            if engine_gone || replies.len() < sent {
                *close_after = true;
                return (503, "engine unavailable\n".into());
            }
            let status = if replies.len() == 1 { status_for_reply(&replies[0]) } else { 200 };
            let mut out = replies.join("\n");
            out.push('\n');
            (status, out)
        }
        _ => (404, format!("no route for {}\n", head.path)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: usize = 1024 * 1024;

    #[test]
    fn head_end_handles_both_terminators() {
        assert_eq!(find_head_end(b"GET / HTTP/1.1\r\n\r\nrest"), Some(18));
        assert_eq!(find_head_end(b"GET / HTTP/1.1\n\nrest"), Some(16));
        assert_eq!(find_head_end(b"GET / HTTP/1.1\r\n"), None);
        assert_eq!(find_head_end(b""), None);
    }

    #[test]
    fn parses_a_minimal_get() {
        let h = parse_request_head("GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n", MB).unwrap();
        assert_eq!(h.method, Method::Get);
        assert_eq!(h.path, "/metrics");
        assert_eq!(h.content_length, None);
        assert!(h.keep_alive, "HTTP/1.1 defaults to keep-alive");
    }

    #[test]
    fn keep_alive_defaults_and_overrides() {
        let h = parse_request_head("GET / HTTP/1.0\r\n\r\n", MB).unwrap();
        assert!(!h.keep_alive, "HTTP/1.0 defaults to close");
        let h =
            parse_request_head("GET / HTTP/1.0\r\nConnection: keep-alive\r\n\r\n", MB).unwrap();
        assert!(h.keep_alive);
        let h = parse_request_head("GET / HTTP/1.1\r\nConnection: close\r\n\r\n", MB).unwrap();
        assert!(!h.keep_alive);
    }

    #[test]
    fn extracts_content_length_and_bearer() {
        let h = parse_request_head(
            "POST /decision HTTP/1.1\r\nContent-Length: 12\r\nAuthorization: Bearer s3cr3t\r\n\r\n",
            MB,
        )
        .unwrap();
        assert_eq!(h.content_length, Some(12));
        assert_eq!(h.bearer.as_deref(), Some("s3cr3t"));
    }

    #[test]
    fn typed_rejections() {
        let e = parse_request_head("DELETE / HTTP/1.1\r\n\r\n", MB).unwrap_err();
        assert_eq!(e.status, 405);
        let e = parse_request_head("GET / HTTP/2.0\r\n\r\n", MB).unwrap_err();
        assert_eq!(e.status, 505);
        let e = parse_request_head("POST /predict HTTP/1.1\r\n\r\n", MB).unwrap_err();
        assert_eq!(e.status, 411, "POST without content-length");
        let e = parse_request_head(
            "POST /predict HTTP/1.1\r\nContent-Length: 9999\r\n\r\n",
            100,
        )
        .unwrap_err();
        assert_eq!(e.status, 413, "declared body over the limit");
        let e = parse_request_head("GET / HTTP/1.1\r\nContent-Length: 4\r\n\r\n", MB).unwrap_err();
        assert_eq!(e.status, 400, "GET with a body");
        let e = parse_request_head("GET / HTTP/1.1\r\nno-colon-here\r\n\r\n", MB).unwrap_err();
        assert_eq!(e.status, 400);
        let e = parse_request_head("GET nopath HTTP/1.1\r\n\r\n", MB).unwrap_err();
        assert_eq!(e.status, 400);
        let e = parse_request_head("GET /\r\n\r\n", MB).unwrap_err();
        assert_eq!(e.status, 400, "two-token request line");
        let e = parse_request_head("\r\n\r\n", MB).unwrap_err();
        assert_eq!(e.status, 400, "no request line at all");
        let many = "X: y\r\n".repeat(MAX_HEADERS + 1);
        let e = parse_request_head(&format!("GET / HTTP/1.1\r\n{many}\r\n"), MB).unwrap_err();
        assert_eq!(e.status, 431);
        assert!(e.to_string().contains("431"), "{e}");
    }

    #[test]
    fn whole_request_validation() {
        assert!(validate_request_text("GET /healthz HTTP/1.1\r\n\r\n", MB).is_ok());
        let ok = "POST /decision HTTP/1.1\r\nContent-Length: 6\r\n\r\n1 2 3\n";
        assert!(validate_request_text(ok, MB).is_ok());
        let e = validate_request_text("GET /healthz HTTP/1.1\r\n", MB).unwrap_err();
        assert_eq!(e.status, 400, "no head terminator");
        let short = "POST /decision HTTP/1.1\r\nContent-Length: 60\r\n\r\n1 2 3\n";
        let e = validate_request_text(short, MB).unwrap_err();
        assert_eq!(e.status, 400, "body shorter than declared");
    }

    #[test]
    fn split_multibyte_body_is_a_400_not_a_panic() {
        // Content-Length lands mid-way through the 3-byte '€' so the
        // live reader would slice an invalid UTF-8 body out of the
        // stream; the whole request text is itself valid UTF-8.
        let req = "POST /decision HTTP/1.1\r\nContent-Length: 5\r\n\r\n1 2 €\n";
        let e = validate_request_text(req, MB).unwrap_err();
        assert_eq!(e.status, 400);
        assert!(e.reason.contains("utf-8"), "{e}");
    }

    #[test]
    fn reply_status_mapping() {
        assert_eq!(status_for_reply("ok -1 margin=-1.2500"), 200);
        assert_eq!(status_for_reply("err queue full (256 pending); request rejected"), 503);
        assert_eq!(status_for_reply("err request shed: queue overflowed while waiting"), 503);
        assert_eq!(
            status_for_reply("err deadline exceeded: waited 120ms against a 50ms deadline"),
            504
        );
        assert_eq!(status_for_reply("err unknown model \"champ\""), 404);
        assert_eq!(status_for_reply("err io: connection reset"), 500);
        assert_eq!(status_for_reply("err bad request: bad feature value \"x\""), 400);
    }
}

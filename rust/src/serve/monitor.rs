//! Drift monitoring for served traffic.
//!
//! A deployed budget SVM degrades silently: the input distribution
//! shifts, the frozen support vectors stop covering it, and decision
//! margins collapse toward the boundary long before anyone re-labels
//! data.  [`Monitor`] watches both signals on the live request stream:
//!
//! * a **rolling decision-margin histogram** — every served decision
//!   `f(x)` lands in one of [`MARGIN_BINS`] fixed `|f|` bins (width
//!   0.25, last bin open-ended).  A healthy tuned model concentrates
//!   mass well away from bin 0; growing
//!   [`Monitor::low_margin_fraction`] is the earliest drift tell,
//!   available with **zero** labels.
//! * a **label-feedback accuracy window** — when callers later learn
//!   ground truth (the `feedback` protocol verb), the hit/miss stream
//!   feeds a bounded window, and every `window/2` feedbacks the monitor
//!   appends an [`EvalPoint`] to the same history format the training
//!   loop's eval machinery records (`TrainOutput::history`), so
//!   training curves and serving curves plot on one axis.
//!
//! The monitor is passive arithmetic on served values — it never
//! touches the model or the request path.

use crate::solver::bsgd::EvalPoint;
use std::collections::VecDeque;
use std::time::Instant;

/// Number of `|decision|` histogram bins (width 0.25; the last bin
/// collects everything ≥ 3.75).
pub const MARGIN_BINS: usize = 16;
const BIN_WIDTH: f64 = 0.25;

/// Degradation totals pushed in by the serving layer so one `stats`
/// payload covers both drift (margins, accuracy) and overload/fault
/// behavior (shedding, deadlines, connection policing).  The monitor
/// itself never computes these — it is a passive carrier.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DegradeTotals {
    /// Requests shed by queue policy ([`crate::serve::ShedPolicy`]).
    pub shed: u64,
    /// Requests expired by the per-request deadline.
    pub expired: u64,
    /// Connections closed for idling past the idle timeout.
    pub idle_timeouts: u64,
    /// Protocol lines rejected for exceeding the line-length cap.
    pub oversize_lines: u64,
    /// Connections turned away at the connection cap (`err busy`).
    pub busy_rejected: u64,
}

/// A point-in-time drift summary (the `stats` protocol verb's payload).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DriftReport {
    /// Decisions recorded.
    pub served: u64,
    /// Fraction of served decisions with `|f| <` one bin width — mass
    /// piling up against the boundary.
    pub low_margin_fraction: f64,
    /// Mean `|f|` over everything served.
    pub mean_abs_margin: f64,
    /// Accuracy over the current feedback window (`None` until the
    /// first feedback arrives).
    pub window_accuracy: Option<f64>,
    /// Labelled feedbacks seen.
    pub feedback_seen: u64,
    /// Overload / fault-handling totals (see [`DegradeTotals`]).
    pub degrade: DegradeTotals,
}

/// Rolling margin histogram + label-feedback accuracy window; see the
/// [module docs](self).
pub struct Monitor {
    bins: [u64; MARGIN_BINS],
    served: u64,
    abs_sum: f64,
    window: VecDeque<bool>,
    window_cap: usize,
    feedback_seen: u64,
    history: Vec<EvalPoint>,
    started: Instant,
    degrade: DegradeTotals,
}

impl Monitor {
    /// `window` bounds the feedback accuracy window (0 is clamped to 1).
    pub fn new(window: usize) -> Self {
        Self {
            bins: [0; MARGIN_BINS],
            served: 0,
            abs_sum: 0.0,
            window: VecDeque::new(),
            window_cap: window.max(1),
            feedback_seen: 0,
            history: Vec::new(),
            started: Instant::now(),
            degrade: DegradeTotals::default(),
        }
    }

    /// Replace the degradation totals (monotone counters owned by the
    /// serving layer; the monitor only reports them).
    pub fn set_degradation(&mut self, totals: DegradeTotals) {
        self.degrade = totals;
    }

    /// Record one served decision value (histogram + counters).
    pub fn record(&mut self, decision: f64) {
        let b = if decision.is_finite() {
            ((decision.abs() / BIN_WIDTH) as usize).min(MARGIN_BINS - 1)
        } else {
            MARGIN_BINS - 1
        };
        self.bins[b] += 1;
        self.served += 1;
        if decision.is_finite() {
            self.abs_sum += decision.abs();
        }
    }

    /// Record one labelled feedback: was the served `decision` correct
    /// for ground-truth label `y` (±1)?  Returns the hit/miss verdict.
    /// Every `window/2` feedbacks the rolling accuracy is snapshotted
    /// into the eval history (`n_svs` is the serving model's SV count,
    /// so the point is plottable next to training-time curves).
    pub fn feedback(&mut self, decision: f64, y: f32, n_svs: usize) -> bool {
        let predicted = if decision >= 0.0 { 1.0 } else { -1.0 };
        let hit = predicted == y;
        if self.window.len() == self.window_cap {
            self.window.pop_front();
        }
        self.window.push_back(hit);
        self.feedback_seen += 1;
        let cadence = (self.window_cap / 2).max(1) as u64;
        if self.feedback_seen % cadence == 0 {
            let acc = self.window_accuracy().unwrap_or(0.0);
            self.history.push(EvalPoint {
                step: self.feedback_seen,
                accuracy: acc,
                n_svs,
                elapsed_s: self.started.elapsed().as_secs_f64(),
            });
        }
        hit
    }

    /// Accuracy over the current window (`None` before any feedback).
    pub fn window_accuracy(&self) -> Option<f64> {
        if self.window.is_empty() {
            return None;
        }
        let hits = self.window.iter().filter(|&&h| h).count();
        Some(hits as f64 / self.window.len() as f64)
    }

    /// Fraction of served decisions in the lowest `|f|` bin.
    pub fn low_margin_fraction(&self) -> f64 {
        if self.served == 0 {
            return 0.0;
        }
        self.bins[0] as f64 / self.served as f64
    }

    /// The raw histogram (bin `i` counts `|f| ∈ [0.25·i, 0.25·(i+1))`,
    /// last bin open-ended).
    pub fn histogram(&self) -> &[u64; MARGIN_BINS] {
        &self.bins
    }

    /// Accuracy snapshots in training-eval format ([`EvalPoint`]),
    /// appended every `window/2` feedbacks.
    pub fn history(&self) -> &[EvalPoint] {
        &self.history
    }

    /// Current drift summary.
    pub fn report(&self) -> DriftReport {
        DriftReport {
            served: self.served,
            low_margin_fraction: self.low_margin_fraction(),
            mean_abs_margin: if self.served == 0 { 0.0 } else { self.abs_sum / self.served as f64 },
            window_accuracy: self.window_accuracy(),
            feedback_seen: self.feedback_seen,
            degrade: self.degrade,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_bins_by_abs_margin() {
        let mut m = Monitor::new(8);
        m.record(0.1); // bin 0
        m.record(-0.1); // bin 0
        m.record(0.6); // bin 2
        m.record(100.0); // last bin
        m.record(f64::NAN); // last bin, excluded from the mean
        assert_eq!(m.histogram()[0], 2);
        assert_eq!(m.histogram()[2], 1);
        assert_eq!(m.histogram()[MARGIN_BINS - 1], 2);
        let r = m.report();
        assert_eq!(r.served, 5);
        assert!((r.low_margin_fraction - 0.4).abs() < 1e-12);
        assert!((r.mean_abs_margin - (0.1 + 0.1 + 0.6 + 100.0) / 5.0).abs() < 1e-12);
    }

    #[test]
    fn feedback_window_rolls() {
        let mut m = Monitor::new(4);
        assert_eq!(m.window_accuracy(), None);
        // 4 hits, then 4 misses: window of 4 forgets the hits
        for _ in 0..4 {
            assert!(m.feedback(1.0, 1.0, 10));
        }
        assert_eq!(m.window_accuracy(), Some(1.0));
        for _ in 0..4 {
            assert!(!m.feedback(1.0, -1.0, 10));
        }
        assert_eq!(m.window_accuracy(), Some(0.0));
        let r = m.report();
        assert_eq!(r.feedback_seen, 8);
        assert_eq!(r.window_accuracy, Some(0.0));
    }

    #[test]
    fn history_snapshots_at_half_window_cadence() {
        let mut m = Monitor::new(4);
        for k in 0..7 {
            m.feedback(1.0, if k % 2 == 0 { 1.0 } else { -1.0 }, 33);
        }
        // cadence = 2 => snapshots at feedback 2, 4, 6
        assert_eq!(m.history().len(), 3);
        assert_eq!(m.history()[0].step, 2);
        assert_eq!(m.history()[2].step, 6);
        assert!(m.history().iter().all(|p| p.n_svs == 33));
        assert!(m.history().iter().all(|p| (0.0..=1.0).contains(&p.accuracy)));
    }

    #[test]
    fn degradation_totals_pass_through_report() {
        let mut m = Monitor::new(2);
        assert_eq!(m.report().degrade, DegradeTotals::default());
        let d = DegradeTotals {
            shed: 3,
            expired: 2,
            idle_timeouts: 1,
            oversize_lines: 4,
            busy_rejected: 5,
        };
        m.set_degradation(d);
        assert_eq!(m.report().degrade, d);
    }

    #[test]
    fn boundary_decision_counts_as_positive() {
        // f = 0.0 predicts +1 — must match Predictor::predict1 exactly
        let mut m = Monitor::new(2);
        assert!(m.feedback(0.0, 1.0, 1));
        assert!(!m.feedback(0.0, -1.0, 1));
    }
}

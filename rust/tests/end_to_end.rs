//! End-to-end system test: the full three-layer stack on a real small
//! workload, including the XLA hot path when artifacts are present.
//! A scaled-down version of `examples/train_adult.rs` suitable for CI.

use mmbsgd::config::{BackendChoice, TrainConfig};
use mmbsgd::coordinator::build_backend;
use mmbsgd::data::synth::{dataset, SynthSpec};
use mmbsgd::runtime::ArtifactRegistry;
use mmbsgd::solver::{bsgd, NoopObserver};

fn artifacts_available() -> bool {
    cfg!(feature = "xla") && ArtifactRegistry::load(&ArtifactRegistry::default_dir()).is_ok()
}

fn adult_cfg(n: usize, backend: BackendChoice) -> TrainConfig {
    let spec = SynthSpec::adult_like(1.0);
    TrainConfig {
        lambda: TrainConfig::lambda_from_c(spec.c, n),
        gamma: spec.gamma,
        budget: 48,
        mergees: 4,
        epochs: 1,
        seed: 1,
        eval_every: 0,
        backend,
        ..TrainConfig::default()
    }
}

#[test]
fn native_end_to_end_adult_twin() {
    let split = dataset(&SynthSpec::adult_like(0.03), 1);
    let cfg = adult_cfg(split.train.len(), BackendChoice::Native);
    let mut backend = build_backend(cfg.backend).unwrap();
    let out = bsgd::train_full(&split.train, &cfg, backend.as_mut(), Some(&split.test), &mut NoopObserver)
        .unwrap();
    let acc = bsgd::evaluate(&out.model, backend.as_mut(), &split.test);
    // ADULT twin: majority class ~76%; a working model must beat it.
    assert!(acc > 0.78, "accuracy {acc}");
    assert!(out.maintenance_events > 0);
    assert!(out.model.svs.len() <= 48);
}

#[test]
fn hybrid_end_to_end_matches_native_accuracy() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        return;
    }
    let split = dataset(&SynthSpec::adult_like(0.01), 2);
    let cfg_n = adult_cfg(split.train.len(), BackendChoice::Native);
    let mut be_n = build_backend(cfg_n.backend).unwrap();
    let out_n = bsgd::train_full(&split.train, &cfg_n, be_n.as_mut(), None, &mut NoopObserver).unwrap();
    let acc_n = bsgd::evaluate(&out_n.model, be_n.as_mut(), &split.test);

    let cfg_h = adult_cfg(split.train.len(), BackendChoice::Hybrid);
    let mut be_h = build_backend(cfg_h.backend).unwrap();
    let out_h = bsgd::train_full(&split.train, &cfg_h, be_h.as_mut(), None, &mut NoopObserver).unwrap();
    let acc_h = bsgd::evaluate(&out_h.model, be_h.as_mut(), &split.test);

    // Same stream, same algorithm, different arithmetic precision in the
    // merge scoring: model trajectories can diverge on near-ties, but the
    // resulting accuracy must be comparable.
    assert!(
        (acc_n - acc_h).abs() < 0.06,
        "native {acc_n} vs hybrid {acc_h} diverged"
    );
    assert!(out_h.model.svs.len() <= 48);
    assert_eq!(out_n.steps, out_h.steps);
}

#[test]
fn full_xla_end_to_end_small() {
    if !artifacts_available() {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        return;
    }
    // Tiny run with EVERYTHING through PJRT (margin1 included): proves
    // the rust binary can train with python fully out of the loop and
    // all numerics coming from the AOT artifacts.
    let split = dataset(&SynthSpec::skin_like(0.0008), 3);
    let mut cfg = adult_cfg(split.train.len(), BackendChoice::Xla);
    let spec = SynthSpec::skin_like(1.0);
    cfg.gamma = spec.gamma;
    cfg.lambda = TrainConfig::lambda_from_c(spec.c, split.train.len());
    cfg.budget = 16;
    let mut backend = build_backend(cfg.backend).unwrap();
    let out = bsgd::train_full(&split.train, &cfg, backend.as_mut(), None, &mut NoopObserver).unwrap();
    let acc = bsgd::evaluate(&out.model, backend.as_mut(), &split.test);
    assert!(acc > 0.7, "xla-backend accuracy {acc}");
    assert!(out.model.svs.len() <= 16);
}

//! Serving-subsystem acceptance tests (ISSUE 4):
//!
//! * micro-batcher bit-parity: batched decisions are bit-identical to
//!   sequential `Predictor::decision1` for B ∈ {1, 7, 64};
//! * shed-policy behaviour at a full queue (`reject` vs `oldest`);
//! * deterministic weighted A/B routing: same key ⇒ same model, across
//!   independently built registries and across threads;
//! * a loopback TCP round-trip of the line protocol, including
//!   malformed-input errors, `stats`, `swap-model`, and `shutdown`.

use mmbsgd::config::TrainConfig;
use mmbsgd::data::synth::{dataset, SynthSpec};
use mmbsgd::data::Split;
use mmbsgd::error::ServeError;
use mmbsgd::model::SvmModel;
use mmbsgd::runtime::NativeBackend;
use mmbsgd::serve::{
    serve, BatchEngine, ModelRegistry, Predictor, RouteSpec, ServeOptions, ServeReport,
    ShedPolicy,
};
use mmbsgd::solver::bsgd;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::Duration;

fn trained(seed: u64, budget: usize) -> (SvmModel, Split) {
    let split = dataset(&SynthSpec::ijcnn_like(0.01), 2);
    let cfg = TrainConfig {
        lambda: 1e-3,
        gamma: 2.0,
        budget,
        mergees: 3,
        seed,
        ..TrainConfig::default()
    };
    (bsgd::train(&split.train, &cfg).unwrap().model, split)
}

fn registry_of(models: Vec<(&str, SvmModel)>, seed: u64) -> ModelRegistry {
    let mut reg = ModelRegistry::new(Box::new(NativeBackend::new()), seed);
    for (name, m) in models {
        reg.insert(name, m).unwrap();
    }
    reg
}

#[test]
fn batched_decisions_bit_match_sequential_decision1() {
    let (model, split) = trained(5, 24);
    let mut reference = Predictor::native(model.clone()).unwrap();
    for batch in [1usize, 7, 64] {
        let mut reg = registry_of(vec![("m", model.clone())], 1);
        let mut eng = BatchEngine::new(batch, 1024, ShedPolicy::Reject);
        let n = batch.min(split.test.len());
        let ids: Vec<u64> = (0..n)
            .map(|i| eng.submit(&reg, None, split.test.x.row(i).to_vec()).unwrap())
            .collect();
        let res = eng.flush(&mut reg);
        assert_eq!(res.len(), n, "batch {batch}");
        for ((id, r), i) in res.into_iter().zip(0..n) {
            assert_eq!(id, ids[i]);
            let d = r.unwrap();
            let want = reference.decision1(split.test.x.row(i)).unwrap();
            assert_eq!(
                d.value.to_bits(),
                want.to_bits(),
                "batch {batch} row {i}: {} vs {want}",
                d.value
            );
        }
    }
}

#[test]
fn batched_decisions_bit_match_under_threads() {
    // Thread count is a wall-clock knob, never a numerics knob — the
    // same guarantee the tile engine gives training.
    let (model, split) = trained(7, 32);
    let n = 40.min(split.test.len());
    let mut want = Vec::new();
    {
        let mut reg = registry_of(vec![("m", model.clone())], 1);
        let mut eng = BatchEngine::new(64, 1024, ShedPolicy::Reject);
        for i in 0..n {
            eng.submit(&reg, None, split.test.x.row(i).to_vec()).unwrap();
        }
        for (_, r) in eng.flush(&mut reg) {
            want.push(r.unwrap().value);
        }
    }
    for threads in [2usize, 4] {
        let mut reg = registry_of(vec![("m", model.clone())], 1);
        reg.set_threads(threads);
        let mut eng = BatchEngine::new(64, 1024, ShedPolicy::Reject);
        for i in 0..n {
            eng.submit(&reg, None, split.test.x.row(i).to_vec()).unwrap();
        }
        for ((_, r), w) in eng.flush(&mut reg).into_iter().zip(&want) {
            assert_eq!(r.unwrap().value.to_bits(), w.to_bits(), "threads {threads}");
        }
    }
}

#[test]
fn shed_policies_at_full_queue() {
    let (model, split) = trained(9, 16);
    let q = |i: usize| split.test.x.row(i).to_vec();

    // reject: the new request is refused, every admitted one answers
    let mut reg = registry_of(vec![("m", model.clone())], 1);
    let mut eng = BatchEngine::new(8, 4, ShedPolicy::Reject);
    for i in 0..4 {
        eng.submit(&reg, None, q(i)).unwrap();
    }
    assert_eq!(
        eng.submit(&reg, None, q(4)).unwrap_err(),
        ServeError::QueueFull { limit: 4 }
    );
    let res = eng.flush(&mut reg);
    assert_eq!(res.len(), 4);
    assert!(res.iter().all(|(_, r)| r.is_ok()));

    // oldest: the head of the queue is displaced with a typed error
    let mut reg = registry_of(vec![("m", model)], 1);
    let mut eng = BatchEngine::new(8, 4, ShedPolicy::Oldest);
    let first = eng.submit(&reg, None, q(0)).unwrap();
    for i in 1..5 {
        eng.submit(&reg, None, q(i)).unwrap();
    }
    assert_eq!(eng.queued(), 4);
    let res = eng.flush(&mut reg);
    assert_eq!(res.len(), 5);
    assert_eq!(res[0].0, first);
    assert_eq!(res[0].1, Err(ServeError::Shed));
    assert!(res.iter().skip(1).all(|(_, r)| r.is_ok()));
    assert_eq!(eng.stats().shed, 1);
}

#[test]
fn pool_reuse_no_spawns_per_margins_pass() {
    // The persistent worker pool is created once by `set_threads`;
    // after that, every micro-batch flush — including B=1 batches and
    // the single-query `Predictor` path — must hand work to the parked
    // workers instead of spawning.  The spawn counter is per-pool, so
    // concurrent tests cannot disturb it.
    let (model, split) = trained(5, 24);

    let mut reg = registry_of(vec![("m", model.clone())], 1);
    assert_eq!(reg.set_threads(2), 2);
    let spawns_after_setup = reg.worker_spawns();
    assert_eq!(spawns_after_setup, 1, "a 2-wide pool spawns exactly one worker");
    let mut eng = BatchEngine::new(8, 64, ShedPolicy::Reject);
    for round in 0..50usize {
        // mixed batch sizes, including the B=1 micro-batch
        let n = 1 + (round % 3);
        for i in 0..n {
            eng.submit(&reg, None, split.test.x.row(i).to_vec()).unwrap();
        }
        let res = eng.flush(&mut reg);
        assert_eq!(res.len(), n);
        assert!(res.iter().all(|(_, r)| r.is_ok()));
    }
    // A batch wide enough to shard (> TILE_Q rows with 2 workers)
    // actually hands work to the parked threads — still no spawns.
    let wide_rows: Vec<Vec<f32>> = (0..70)
        .map(|i| split.test.x.row(i % split.test.len()).to_vec())
        .collect();
    let wide = mmbsgd::data::DenseMatrix::from_rows(wide_rows);
    let mut out = vec![0.0f64; wide.rows()];
    for _ in 0..20 {
        reg.decision_batch_into("m", &wide, &mut out).unwrap();
    }
    assert_eq!(
        reg.worker_spawns(),
        spawns_after_setup,
        "50 flushes + 20 sharded batch passes must not create a single OS thread (pool_reuse)"
    );

    // the single-model Predictor path shares the same guarantee
    let mut p = Predictor::native(model).unwrap();
    assert_eq!(p.set_threads(2), 2);
    let before = p.worker_spawns();
    assert_eq!(before, 1);
    for i in 0..40.min(split.test.len()) {
        p.decision1(split.test.x.row(i)).unwrap();
    }
    let batch = mmbsgd::data::DenseMatrix::from_rows(vec![split.test.x.row(0).to_vec()]);
    for _ in 0..40 {
        p.decision_batch(&batch).unwrap();
    }
    assert_eq!(p.worker_spawns(), before, "predictor requests must reuse the pool");
}

#[test]
fn ab_routing_is_deterministic_across_registries_and_threads() {
    let (a, _) = trained(11, 16);
    let (b, _) = trained(12, 16);
    let spec = RouteSpec::new(vec![("a".into(), 2), ("b".into(), 1)]).unwrap();
    let build = || {
        let mut reg = registry_of(vec![("a", a.clone()), ("b", b.clone())], 77);
        reg.set_route(spec.clone()).unwrap();
        reg
    };
    let keys: Vec<String> = (0..500).map(|k| format!("req-{k}")).collect();
    let reference: Vec<String> = {
        let reg = build();
        keys.iter().map(|k| reg.route_for(k.as_bytes()).unwrap()).collect()
    };
    // a fresh registry agrees key-for-key
    let again = build();
    for (k, want) in keys.iter().zip(&reference) {
        assert_eq!(&again.route_for(k.as_bytes()).unwrap(), want);
    }
    // and so does every thread over its own registry
    std::thread::scope(|s| {
        for _ in 0..4 {
            let keys = &keys;
            let reference = &reference;
            let a = &a;
            let b = &b;
            let spec = &spec;
            s.spawn(move || {
                let mut reg = registry_of(vec![("a", a.clone()), ("b", b.clone())], 77);
                reg.set_route(spec.clone()).unwrap();
                for (k, want) in keys.iter().zip(reference) {
                    assert_eq!(&reg.route_for(k.as_bytes()).unwrap(), want);
                }
            });
        }
    });
    // the 2:1 weighting actually splits traffic (loose bounds)
    let to_a = reference.iter().filter(|m| m.as_str() == "a").count();
    assert!((250..=420).contains(&to_a), "arm a got {to_a} of 500");
}

/// Run a one-model server on a loopback port while `client` drives it;
/// returns the server's final report plus whatever the client observed.
/// The client must eventually send `shutdown` (or trip a guard that
/// stops the server) or the scope never joins.
fn serve_with<R: Send>(
    opts: ServeOptions,
    model: SvmModel,
    client: impl FnOnce(SocketAddr) -> R + Send,
) -> (ServeReport, R) {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let reg = registry_of(vec![("m", model)], 1);
    let mut seen = None;
    let report = std::thread::scope(|s| {
        let h = s.spawn(move || client(addr));
        let report = serve(listener, reg, &opts).unwrap();
        seen = Some(h.join().unwrap());
        report
    });
    (report, seen.unwrap())
}

fn fmt_row(x: &[f32]) -> String {
    x.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(" ")
}

/// Pipeline `payload` in one write, then collect `expect` reply lines.
fn pipeline(addr: SocketAddr, payload: String, expect: usize) -> Vec<String> {
    let stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut w = stream.try_clone().unwrap();
    w.write_all(payload.as_bytes()).unwrap();
    w.flush().unwrap();
    let mut rd = BufReader::new(stream);
    let mut replies = Vec::new();
    for _ in 0..expect {
        let mut line = String::new();
        rd.read_line(&mut line).unwrap();
        replies.push(line.trim().to_string());
    }
    replies
}

/// An oversized line and a non-UTF-8 line each answer a typed `err` in
/// FIFO position, the counter shows in `stats`, and the connection and
/// server both survive to answer the next command.
#[test]
fn oversized_and_garbage_lines_answer_err_and_server_survives() {
    let (model, _) = trained(5, 16);
    let opts = ServeOptions { max_line_bytes: 64, ..ServeOptions::default() };
    let (report, replies) = serve_with(opts, model, move |addr| {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut w = stream.try_clone().unwrap();
        let big = format!("predict {}\n", "1 ".repeat(100)); // ~208 bytes > 64
        w.write_all(big.as_bytes()).unwrap();
        w.write_all(&[0xff, 0xfe, b'\n']).unwrap(); // not UTF-8
        w.write_all(b"stats\nshutdown\n").unwrap();
        w.flush().unwrap();
        let mut rd = BufReader::new(stream);
        let mut replies = Vec::new();
        for _ in 0..4 {
            let mut line = String::new();
            rd.read_line(&mut line).unwrap();
            replies.push(line.trim().to_string());
        }
        replies
    });
    assert!(replies[0].starts_with("err line exceeds 64 bytes"), "{}", replies[0]);
    assert!(replies[1].starts_with("err "), "{}", replies[1]);
    assert!(replies[2].starts_with("ok served=0"), "{}", replies[2]);
    assert!(replies[2].contains("oversize=1"), "{}", replies[2]);
    assert_eq!(replies[3], "ok bye");
    assert_eq!(report.proto.oversize_lines, 1);
}

/// A connection that goes silent past the idle timeout is told why and
/// closed; the server keeps serving new connections.
#[test]
fn idle_connections_time_out_with_a_typed_line() {
    let (model, _) = trained(5, 16);
    let opts =
        ServeOptions { idle_timeout: Duration::from_millis(150), ..ServeOptions::default() };
    let (report, (idle_line, eof)) = serve_with(opts, model, move |addr| {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut rd = BufReader::new(stream);
        // send nothing: the server must evict us, with an explanation
        let mut line = String::new();
        rd.read_line(&mut line).unwrap();
        let mut rest = String::new();
        let eof = rd.read_line(&mut rest).unwrap();
        // a fresh connection still works and shuts the server down
        let bye = pipeline(addr, "shutdown\n".into(), 1);
        assert_eq!(bye[0], "ok bye");
        (line.trim().to_string(), eof)
    });
    assert_eq!(idle_line, "err idle timeout, closing connection");
    assert_eq!(eof, 0, "the server must close the socket after the notice");
    assert_eq!(report.proto.idle_timeouts, 1);
    assert_eq!(report.connections, 2);
}

/// Past `max_conns`, new connections get `err busy` and are closed —
/// established connections are unaffected.
#[test]
fn connection_cap_turns_extras_away_with_err_busy() {
    let (model, _) = trained(5, 16);
    let opts = ServeOptions { max_conns: 1, ..ServeOptions::default() };
    let (report, (busy, bye)) = serve_with(opts, model, move |addr| {
        let a = TcpStream::connect(addr).unwrap();
        a.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut wa = a.try_clone().unwrap();
        let mut ra = BufReader::new(a);
        // prove A is established server-side before B tries
        wa.write_all(b"stats\n").unwrap();
        let mut line = String::new();
        ra.read_line(&mut line).unwrap();
        assert!(line.starts_with("ok "), "{line}");
        let b = TcpStream::connect(addr).unwrap();
        b.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut rb = BufReader::new(b);
        let mut busy = String::new();
        rb.read_line(&mut busy).unwrap();
        wa.write_all(b"shutdown\n").unwrap();
        let mut bye = String::new();
        ra.read_line(&mut bye).unwrap();
        (busy.trim().to_string(), bye.trim().to_string())
    });
    assert_eq!(busy, "err busy: connection limit reached");
    assert_eq!(bye, "ok bye");
    assert_eq!(report.proto.busy_rejected, 1);
    assert_eq!(report.connections, 1, "the refused connection is not counted as served");
}

/// With a (deliberately unmeetable) per-request deadline, every
/// request answers the typed deadline error instead of hanging, the
/// expiry counter shows in `stats`, and shutdown still drains cleanly.
#[test]
fn expired_requests_answer_typed_deadline_errors() {
    let (model, split) = trained(5, 16);
    let opts =
        ServeOptions { deadline: Duration::from_nanos(1), ..ServeOptions::default() };
    let payload: String = (0..3)
        .map(|i| format!("predict {}\n", fmt_row(split.test.x.row(i))))
        .chain(["stats\n".to_string(), "shutdown\n".to_string()])
        .collect();
    let (report, replies) =
        serve_with(opts, model, move |addr| pipeline(addr, payload, 5));
    for r in &replies[..3] {
        assert!(r.starts_with("err deadline exceeded"), "{r}");
    }
    assert!(replies[3].contains("expired=3"), "{}", replies[3]);
    assert_eq!(replies[4], "ok bye");
    assert_eq!(report.engine.expired, 3);
    assert_eq!(report.engine.served, 0);
}

/// `shutdown` behind pipelined work is a drain, not an abort: every
/// in-flight request is answered before the goodbye.
#[test]
fn shutdown_drains_pipelined_requests_before_closing() {
    let (model, split) = trained(5, 24);
    let n = 5usize;
    let payload: String = (0..n)
        .map(|i| format!("predict {}\n", fmt_row(split.test.x.row(i))))
        .chain(["shutdown\n".to_string()])
        .collect();
    let (report, replies) = serve_with(ServeOptions::default(), model, move |addr| {
        pipeline(addr, payload, n + 1)
    });
    for r in &replies[..n] {
        assert!(r.starts_with("ok "), "{r}");
    }
    assert_eq!(replies[n], "ok bye");
    assert_eq!(report.engine.served, n as u64);
}

/// Drive the full TCP server over a loopback socket: pipelined
/// predict/decision, a malformed line mid-stream, stats, swap-model,
/// shutdown — and check the answers against a local Predictor.
#[test]
fn loopback_tcp_round_trip() {
    let (model, split) = trained(5, 24);
    let dir = std::env::temp_dir().join(format!("mmbsgd_serve_test_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let swap_path = dir.join("swap.txt");
    let (swap_model, _) = trained(6, 16);
    swap_model.save(&swap_path).unwrap();

    let mut reference = Predictor::native(model.clone()).unwrap();
    let x0: Vec<f32> = split.test.x.row(0).to_vec();
    let x1: Vec<f32> = split.test.x.row(1).to_vec();
    let want0 = reference.decision1(&x0).unwrap();
    let want1 = reference.decision1(&x1).unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let fmt = |x: &[f32]| {
        x.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(" ")
    };
    let lines = vec![
        format!("decision key=alpha {}", fmt(&x0)),
        format!("predict key=alpha {}", fmt(&x1)),
        "predict 1 2 trailing-garbage".to_string(),
        "no-such-command".to_string(),
        format!("feedback key=alpha +1 {}", fmt(&x0)),
        "stats".to_string(),
        format!("swap-model m {}", swap_path.display()),
        "swap-model ghost /nonexistent".to_string(),
        "stats".to_string(),
        "shutdown".to_string(),
    ];
    let n_lines = lines.len();

    let client = std::thread::spawn(move || {
        let stream = TcpStream::connect(addr).unwrap();
        stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut w = stream.try_clone().unwrap();
        // pipeline everything in one write: replies must still come
        // back one per line, in order
        let payload: String =
            lines.iter().map(|l| format!("{l}\n")).collect::<Vec<_>>().concat();
        w.write_all(payload.as_bytes()).unwrap();
        w.flush().unwrap();
        let mut rd = BufReader::new(stream);
        let mut replies = Vec::new();
        for _ in 0..n_lines {
            let mut line = String::new();
            rd.read_line(&mut line).unwrap();
            replies.push(line.trim().to_string());
        }
        replies
    });

    let reg = registry_of(vec![("m", model)], 1);
    let opts = ServeOptions { batch_max: 8, queue_max: 64, ..ServeOptions::default() };
    let report = serve(listener, reg, &opts).unwrap();
    let replies = client.join().unwrap();
    std::fs::remove_dir_all(&dir).ok();

    assert_eq!(replies.len(), n_lines);
    // decision: exact round-trip of the served bits
    let d0: f64 = replies[0]
        .strip_prefix("ok ")
        .unwrap()
        .split_whitespace()
        .next()
        .unwrap()
        .parse()
        .unwrap();
    assert_eq!(d0.to_bits(), want0.to_bits(), "{} vs {want0}", replies[0]);
    assert!(replies[0].ends_with("m@v1"), "{}", replies[0]);
    // predict: label + decision
    let mut it = replies[1].strip_prefix("ok ").unwrap().split_whitespace();
    let label = it.next().unwrap();
    let d1: f64 = it.next().unwrap().parse().unwrap();
    assert_eq!(label, if want1 >= 0.0 { "+1" } else { "-1" });
    assert_eq!(d1.to_bits(), want1.to_bits());
    // malformed lines answer err without killing the connection
    assert!(replies[2].starts_with("err "), "{}", replies[2]);
    assert!(replies[3].starts_with("err "), "{}", replies[3]);
    // feedback verdict against the known decision sign
    let verdict = if want0 >= 0.0 { "ok hit" } else { "ok miss" };
    assert!(replies[4].starts_with(verdict), "{} (f={want0})", replies[4]);
    // stats carries the counters and the model list
    assert!(replies[5].starts_with("ok served=3"), "{}", replies[5]);
    assert!(replies[5].contains("m@v1:"), "{}", replies[5]);
    assert!(replies[5].contains("feedback=1"), "{}", replies[5]);
    // swap bumps the version; a bad swap is a per-request error
    assert_eq!(replies[6], "ok m@v2");
    assert!(replies[7].starts_with("err "), "{}", replies[7]);
    assert!(replies[8].contains("m@v2:"), "{}", replies[8]);
    assert_eq!(replies[9], "ok bye");
    assert_eq!(report.connections, 1);
    assert_eq!(report.engine.served, 3);
}

//! XLA (AOT artifacts through PJRT) vs native backend equivalence.
//!
//! The native backend in **exact** scoring mode mirrors the L1 kernel
//! math (same golden constants, same GD scheme); these tests pin the
//! two together across artifact shapes.  They require `artifacts/` to
//! exist (`make artifacts`) *and* the `xla` cargo feature with real
//! PJRT bindings, and are skipped with a loud message otherwise —
//! `make test` always builds artifacts first.

use mmbsgd::data::DenseMatrix;
use mmbsgd::model::SvStore;
use mmbsgd::rng::Xoshiro256;
use mmbsgd::runtime::{ArtifactRegistry, Backend, NativeBackend, XlaBackend};

fn artifacts_available() -> bool {
    if !cfg!(feature = "xla") {
        eprintln!("SKIP: built without the `xla` feature — no PJRT backend");
        return false;
    }
    let dir = ArtifactRegistry::default_dir();
    if ArtifactRegistry::load(&dir).is_ok() {
        true
    } else {
        eprintln!(
            "SKIP: no artifacts at {} — run `make artifacts`",
            dir.display()
        );
        false
    }
}

fn xla() -> XlaBackend {
    XlaBackend::new(&ArtifactRegistry::default_dir()).expect("XlaBackend")
}

fn random_store(b: usize, d: usize, seed: u64) -> SvStore {
    let mut rng = Xoshiro256::new(seed);
    let mut s = SvStore::new(d);
    for _ in 0..b {
        let x: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        s.push(&x, rng.next_gaussian() * 0.5);
    }
    s
}

#[test]
fn registry_has_expected_lattice() {
    if !artifacts_available() {
        return;
    }
    let reg = ArtifactRegistry::load(&ArtifactRegistry::default_dir()).unwrap();
    // every entry point present
    for entry in ["margins", "merge_scores", "merge_gd"] {
        assert!(
            reg.artifacts.iter().any(|a| a.entry == entry),
            "missing {entry} artifacts"
        );
    }
    // variant selection picks smallest fitting pads
    let m = reg.find_margins(100, 20, 1).expect("margins variant");
    assert_eq!((m.b_pad, m.d_pad), (128, 32));
    let m = reg.find_margins(129, 20, 256).expect("margins variant");
    assert_eq!((m.b_pad, m.d_pad), (256, 32));
    let s = reg.find_merge_scores(1000, 123).expect("merge_scores variant");
    assert_eq!((s.b_pad, s.d_pad), (1024, 128));
    assert!(reg.find_merge_scores(5000, 20).is_none(), "beyond lattice must be None");
    let g = reg.find_merge_gd(300).expect("merge_gd variant");
    assert_eq!(g.d_pad, 512);
}

#[test]
fn margins_match_native() {
    if !artifacts_available() {
        return;
    }
    let mut x = xla();
    let mut n = NativeBackend::exact();
    for &(b, d, seed) in &[(10usize, 5usize, 1u64), (100, 22, 2), (300, 68, 3)] {
        let svs = random_store(b, d, seed);
        let mut rng = Xoshiro256::new(seed ^ 77);
        let rows: Vec<Vec<f32>> = (0..7)
            .map(|_| (0..d).map(|_| rng.next_gaussian() as f32).collect())
            .collect();
        let q = DenseMatrix::from_rows(rows);
        let gamma = 0.7;
        let mx = x.margins(&svs, gamma, &q);
        let mn = n.margins(&svs, gamma, &q);
        for (a, b_) in mx.iter().zip(&mn) {
            assert!(
                (a - b_).abs() < 1e-3 * (1.0 + b_.abs()),
                "margin mismatch {a} vs {b_} (B={b}, d={d})"
            );
        }
        // single-point margin agrees with batch
        let m1 = x.margin1(&svs, gamma, q.row(0));
        assert!((m1 - mn[0]).abs() < 1e-3 * (1.0 + mn[0].abs()));
    }
}

#[test]
fn merge_scores_match_native() {
    if !artifacts_available() {
        return;
    }
    let mut x = xla();
    let mut n = NativeBackend::exact();
    for &(b, d, seed) in &[(12usize, 3usize, 4u64), (60, 22, 5), (200, 68, 6)] {
        let svs = random_store(b, d, seed);
        let gamma = 1.3;
        let i = svs.min_abs_alpha().unwrap();
        let sx = x.merge_scores(&svs, gamma, i);
        let sn = n.merge_scores(&svs, gamma, i);
        assert!(sx.wd[i].is_infinite() && sn.wd[i].is_infinite());
        let mut rank_x: Vec<usize> = (0..b).filter(|&j| j != i).collect();
        let mut rank_n = rank_x.clone();
        rank_x.sort_by(|&a, &c| sx.wd[a].total_cmp(&sx.wd[c]));
        rank_n.sort_by(|&a, &c| sn.wd[a].total_cmp(&sn.wd[c]));
        // XLA's chosen partner must be ε-optimal under the native scores
        // (exact argmin can flip between f32 and f64 on near-ties).
        let (jx, jn) = (rank_x[0], rank_n[0]);
        assert!(
            sn.wd[jx] <= sn.wd[jn] + 5e-3 * (1.0 + sn.wd[jn].abs()),
            "xla best partner {jx} (native wd {}) not ε-optimal vs {jn} ({}) (B={b}, d={d})",
            sn.wd[jx],
            sn.wd[jn]
        );
        for j in 0..b {
            if j == i {
                continue;
            }
            let (a, c) = (sx.wd[j], sn.wd[j]);
            assert!(
                (a - c).abs() < 5e-3 * (1.0 + c.abs()),
                "wd[{j}] {a} vs {c} (B={b}, d={d})"
            );
            assert!(
                (sx.d2[j] - sn.d2[j]).abs() < 1e-3 * (1.0 + sn.d2[j]),
                "d2[{j}] mismatch"
            );
        }
    }
}

#[test]
fn merge_gd_matches_native() {
    if !artifacts_available() {
        return;
    }
    let mut x = xla();
    let mut n = NativeBackend::exact();
    let mut rng = Xoshiro256::new(9);
    for &m in &[2usize, 3, 5, 10] {
        let d = 8;
        let center: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        let pts_owned: Vec<(Vec<f32>, f64)> = (0..m)
            .map(|_| {
                let p: Vec<f32> = center
                    .iter()
                    .map(|&c| c + 0.3 * rng.next_gaussian() as f32)
                    .collect();
                (p, 0.1 + rng.next_f64() * 0.5)
            })
            .collect();
        let pts: Vec<(&[f32], f64)> =
            pts_owned.iter().map(|(p, a)| (p.as_slice(), *a)).collect();
        let gamma = 0.8;
        let (zx, ax, wx) = x.merge_gd(&pts, gamma);
        let (zn, an, wn) = n.merge_gd(&pts, gamma);
        // Both must find (numerically) equally good merges; the exact z
        // may differ (flat optima), so compare achieved degradation.
        assert!(
            (wx - wn).abs() < 5e-3 * (1.0 + wn.abs()) + 1e-4,
            "M={m}: wd {wx} vs {wn}"
        );
        assert!((ax - an).abs() < 0.05 * (1.0 + an.abs()), "M={m}: a_z {ax} vs {an}");
        assert_eq!(zx.len(), zn.len());
    }
}

#[test]
fn hybrid_backend_routes_consistently() {
    if !artifacts_available() {
        return;
    }
    let mut h = mmbsgd::runtime::HybridBackend::from_default_dir().unwrap();
    let mut n = NativeBackend::exact();
    let svs = random_store(50, 10, 11);
    let q = DenseMatrix::from_rows(vec![vec![0.1f32; 10], vec![-0.2f32; 10]]);
    let gamma = 0.9;
    let hm = h.margins(&svs, gamma, &q);
    let nm = n.margins(&svs, gamma, &q);
    for (a, b) in hm.iter().zip(&nm) {
        assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()));
    }
    assert!((h.margin1(&svs, gamma, q.row(0)) - nm[0]).abs() < 1e-9); // native path: exact
}

//! Fleet acceptance tests (ISSUE 7): live replicas behind the
//! consistent-hash router, driven end-to-end over real sockets.
//!
//! * two replicas + router, one replica killed mid-traffic → every
//!   keyed request is still answered, and the answers are bit-identical
//!   to the pre-kill ones (the surviving replica serves the same model,
//!   and the ring moves only the dead replica's arcs);
//! * a tampered artifact is refused with a typed reason and the
//!   replica keeps serving its last-good version untouched;
//! * `rollback` restores the previous version fleet-wide, answers
//!   return bit-identically to the v1 decisions;
//! * the controller's auto-rollback hook fires when a replica's
//!   feedback-accuracy window degrades, and stays quiet while healthy.
//!
//! Concurrent-router acceptance (ISSUE 10):
//!
//! * keyed answers are bit-identical across every router
//!   `threads` × `pool` combination under concurrent clients;
//! * pooled links are reused across forwards — the total dialed-link
//!   count stays bounded by `replicas × pool` no matter how many
//!   requests flow;
//! * a replica-side idle reap (stale pooled link) recycles the link
//!   and retries on a fresh one without marking the replica dead.

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::time::Duration;

use mmbsgd::config::TrainConfig;
use mmbsgd::data::synth::{dataset, SynthSpec};
use mmbsgd::data::Split;
use mmbsgd::fleet::{run_router, Artifact, Controller, Provenance, ReplicaState, RouterOptions};
use mmbsgd::model::SvmModel;
use mmbsgd::runtime::NativeBackend;
use mmbsgd::serve::{serve_fleet, ModelRegistry, ServeOptions};

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mmbsgd_fleet_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn trained() -> (SvmModel, Split) {
    let split = dataset(&SynthSpec::ijcnn_like(0.01), 2);
    let cfg = TrainConfig {
        lambda: 1e-3,
        gamma: 2.0,
        budget: 24,
        mergees: 3,
        seed: 41,
        ..TrainConfig::default()
    };
    (mmbsgd::solver::bsgd::train(&split.train, &cfg).unwrap().model, split)
}

fn wrap(version: u64, model: &SvmModel) -> Artifact {
    Artifact::wrap("champ", version, model, Provenance::default(), "lut", "auto").unwrap()
}

/// Reparse-copy a model (SvmModel carries no Clone; the text format is
/// the canonical representation anyway).
fn copy_of(model: &SvmModel) -> SvmModel {
    SvmModel::from_text(&model.to_text()).unwrap()
}

fn fmt_row(x: &[f32]) -> String {
    x.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(" ")
}

/// Serve one fleet replica on `listener` until a `shutdown` line.
fn replica_serve(listener: TcpListener, dir: &Path) {
    replica_serve_opts(listener, dir, ServeOptions::default());
}

/// Like [`replica_serve`] with explicit serve options (the broken-link
/// test needs a short replica idle timeout to reap pooled links).
fn replica_serve_opts(listener: TcpListener, dir: &Path, opts: ServeOptions) {
    let mut rep = ReplicaState::new(dir).unwrap();
    let reg = ModelRegistry::new(Box::new(NativeBackend::new()), 7);
    serve_fleet(listener, reg, &opts, &mut rep).unwrap();
}

fn bind() -> (TcpListener, SocketAddr) {
    let l = TcpListener::bind("127.0.0.1:0").unwrap();
    let a = l.local_addr().unwrap();
    (l, a)
}

/// A line-protocol test client: one request line in, one reply out.
struct Client {
    w: TcpStream,
    r: BufReader<TcpStream>,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let s = TcpStream::connect(addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        s.set_nodelay(true).ok();
        let w = s.try_clone().unwrap();
        Client { w, r: BufReader::new(s) }
    }

    fn ask(&mut self, line: &str) -> String {
        self.w.write_all(line.as_bytes()).unwrap();
        self.w.write_all(b"\n").unwrap();
        self.w.flush().unwrap();
        self.read_reply()
    }

    /// Raw length-delimited push (the controller normally does this;
    /// going raw lets a test push bytes the controller would refuse to
    /// produce, e.g. a tampered bundle).
    fn push_raw(&mut self, payload: &str) -> String {
        let msg = format!("push-artifact {}\n{payload}", payload.len());
        self.w.write_all(msg.as_bytes()).unwrap();
        self.w.flush().unwrap();
        self.read_reply()
    }

    fn read_reply(&mut self) -> String {
        let mut reply = String::new();
        self.r.read_line(&mut reply).unwrap();
        reply.trim_end().to_string()
    }
}

/// The decision value token of an `ok <decision> <model>@v<N>` reply
/// (registry versions differ across swaps; the float must not).
fn decision_of(reply: &str) -> String {
    assert!(reply.starts_with("ok "), "{reply}");
    reply.split_ascii_whitespace().nth(1).unwrap().to_string()
}

// ------------------------------------------------ acceptance: failover

/// Two replicas behind the router; one dies mid-traffic.  Every keyed
/// request is still answered, bit-identical to its pre-kill reply: the
/// ring moves only the dead replica's arcs, and both replicas serve
/// the same deterministic model, so even the rerouted keys answer with
/// the exact same bytes.
#[test]
fn router_reroutes_when_a_replica_dies_mid_traffic() {
    let (model, split) = trained();
    let d0 = scratch("route0");
    let d1 = scratch("route1");
    let (l0, a0) = bind();
    let (l1, a1) = bind();
    let (lr, ar) = bind();
    let eps = vec![a0.to_string(), a1.to_string()];
    std::thread::scope(|s| {
        s.spawn(|| replica_serve(l0, &d0));
        s.spawn(|| replica_serve(l1, &d1));
        let ropts = RouterOptions {
            seed: 42,
            vnodes: 64,
            timeout: Duration::from_secs(10),
            // long enough that the dead replica is never re-probed
            // back into rotation inside this test
            probe_every: Duration::from_secs(600),
            ..RouterOptions::default()
        };
        let reps = eps.clone();
        let rh = s.spawn(move || run_router(lr, reps, &ropts).unwrap());

        // control plane: stage + activate v1 on the whole fleet
        let mut ctl = Controller::new(eps.clone(), Duration::from_secs(10));
        for o in ctl.push(&wrap(1, &model), true) {
            assert_eq!(o.result, Ok(1), "replica {} did not converge", o.endpoint);
        }

        // data plane through the router: keyed decisions over one row
        let q = fmt_row(split.test.x.row(0));
        let keys: Vec<String> = (0..48).map(|k| format!("user-{k}")).collect();
        let mut client = Client::connect(ar);
        let before: Vec<String> =
            keys.iter().map(|k| client.ask(&format!("decision key={k} {q}"))).collect();
        for r in &before {
            assert!(r.starts_with("ok "), "{r}");
        }

        // kill replica 0 directly, mid-traffic (`shutdown` goes to the
        // replica, not the router — the router refuses control verbs)
        assert_eq!(Client::connect(a0).ask("shutdown"), "ok bye");

        // every key still answers, and every reply is unchanged
        let after: Vec<String> =
            keys.iter().map(|k| client.ask(&format!("decision key={k} {q}"))).collect();
        assert_eq!(before, after, "failover changed an answer");

        // stop the router, then the surviving replica
        assert_eq!(client.ask("shutdown"), "ok bye");
        let report = rh.join().unwrap();
        assert!(report.forwarded >= 96, "forwarded {}", report.forwarded);
        assert!(report.retried >= 1, "no key was rerouted through the alternate");
        assert_eq!(Client::connect(a1).ask("shutdown"), "ok bye");
    });
    let _ = std::fs::remove_dir_all(&d0);
    let _ = std::fs::remove_dir_all(&d1);
}

// --------------------------------------------- acceptance: tamper gate

/// A bundle with one flipped byte inside the model section is refused
/// with a typed checksum reason; the replica keeps serving v1 and
/// stages nothing.
#[test]
fn tampered_artifact_is_refused_and_replica_stays_last_good() {
    let (model, split) = trained();
    let dir = scratch("tamper");
    let (l, addr) = bind();
    std::thread::scope(|s| {
        s.spawn(|| replica_serve(l, &dir));
        let mut ctl = Controller::new(vec![addr.to_string()], Duration::from_secs(10));
        assert_eq!(ctl.push(&wrap(1, &model), true)[0].result, Ok(1));

        let q = fmt_row(split.test.x.row(0));
        let mut c = Client::connect(addr);
        let v1_reply = c.ask(&format!("decision {q}"));
        assert!(v1_reply.starts_with("ok "), "{v1_reply}");

        // wrap a would-be v2, then flip one digit inside the model
        // section (after end-manifest) keeping the byte length — the
        // manifest still parses, the section checksum must not
        let mut m2 = copy_of(&model);
        m2.bias += 1.0;
        let text = wrap(2, &m2).to_text();
        let cut = text.find("end-manifest\n").unwrap() + "end-manifest\n".len();
        let (head, body) = text.split_at(cut);
        let pos = cut + body.find(|ch: char| ch.is_ascii_digit()).unwrap();
        let mut tampered = text.clone().into_bytes();
        tampered[pos] = if tampered[pos] == b'9' { b'8' } else { tampered[pos] + 1 };
        let tampered = String::from_utf8(tampered).unwrap();
        assert_eq!(tampered.len(), text.len());
        assert_eq!(&tampered[..cut], head);

        let reply = c.push_raw(&tampered);
        assert!(reply.starts_with("err push-artifact:"), "{reply}");
        assert!(reply.contains("checksum"), "tamper reason must name the checksum: {reply}");

        // the never-staged v2 cannot be activated either
        let reply = c.ask("activate champ@v2");
        assert!(reply.starts_with("err") && reply.contains("no staged artifact"), "{reply}");

        // the replica still serves v1, bit-identically, with an empty
        // staging area
        assert_eq!(c.ask(&format!("decision {q}")), v1_reply);
        let status = c.ask("fleet-status");
        assert!(status.contains("champ@v1"), "{status}");
        assert!(status.contains("staged=0"), "{status}");
        assert_eq!(c.ask("shutdown"), "ok bye");
    });
    let _ = std::fs::remove_dir_all(&dir);
}

// ----------------------------------------------- acceptance: rollback

/// Push v1 then v2 across two replicas; `rollback` restores v1
/// fleet-wide and the decision values return bit-identically to the
/// v1 answers (registry version tags move forward — the swap counter
/// is monotonic — but the served function is v1's).
#[test]
fn rollback_restores_previous_version_fleet_wide() {
    let (model, split) = trained();
    let mut m2 = copy_of(&model);
    m2.bias += 1.0; // guaranteed-different decisions
    let d0 = scratch("rb0");
    let d1 = scratch("rb1");
    let (l0, a0) = bind();
    let (l1, a1) = bind();
    let eps = vec![a0.to_string(), a1.to_string()];
    std::thread::scope(|s| {
        s.spawn(|| replica_serve(l0, &d0));
        s.spawn(|| replica_serve(l1, &d1));
        let mut ctl = Controller::new(eps.clone(), Duration::from_secs(10));
        for o in ctl.push(&wrap(1, &model), true) {
            assert_eq!(o.result, Ok(1), "{}", o.endpoint);
        }

        let q = fmt_row(split.test.x.row(0));
        let mut c0 = Client::connect(a0);
        let mut c1 = Client::connect(a1);
        let v1_f = decision_of(&c0.ask(&format!("decision {q}")));
        assert_eq!(decision_of(&c1.ask(&format!("decision {q}"))), v1_f);

        for o in ctl.push(&wrap(2, &m2), true) {
            assert_eq!(o.result, Ok(2), "{}", o.endpoint);
        }
        let v2_f = decision_of(&c0.ask(&format!("decision {q}")));
        assert_ne!(v2_f, v1_f, "v2 must serve a different function");

        for o in ctl.rollback("champ") {
            assert_eq!(o.result, Ok(1), "{}", o.endpoint);
        }
        for ep in &eps {
            assert_eq!(ctl.acked(ep, "champ"), Some(1));
        }
        assert_eq!(decision_of(&c0.ask(&format!("decision {q}"))), v1_f);
        assert_eq!(decision_of(&c1.ask(&format!("decision {q}"))), v1_f);

        // both replicas report v1 active with v2 as the rollback's
        // own last-good (a rollback can itself be rolled back)
        for out in ctl.status() {
            assert!(out.is_alive(), "{}", out.endpoint);
            let line = out.result.unwrap();
            assert!(line.contains("champ@v1:lg=2"), "{}: {line}", out.endpoint);
        }
        assert_eq!(c0.ask("shutdown"), "ok bye");
        assert_eq!(c1.ask("shutdown"), "ok bye");
    });
    let _ = std::fs::remove_dir_all(&d0);
    let _ = std::fs::remove_dir_all(&d1);
}

// ------------------------------------------ acceptance: auto-rollback

/// The controller's registry-level auto-rollback hook: quiet while no
/// feedback window exists, fires fleet-wide once served feedback
/// degrades a replica's accuracy window below the threshold.
#[test]
fn auto_rollback_fires_on_degraded_accuracy_window() {
    let (model, split) = trained();
    let mut m2 = copy_of(&model);
    m2.bias += 1.0;
    let dir = scratch("auto");
    let (l, addr) = bind();
    std::thread::scope(|s| {
        s.spawn(|| replica_serve(l, &dir));
        let mut ctl = Controller::new(vec![addr.to_string()], Duration::from_secs(10));
        assert_eq!(ctl.push(&wrap(1, &model), true)[0].result, Ok(1));
        assert_eq!(ctl.push(&wrap(2, &m2), true)[0].result, Ok(2));

        // healthy (no feedback yet → no accuracy window): stays quiet
        assert!(ctl.maybe_auto_rollback("champ", 0.9).is_none());

        // label-contradicting traffic: every feedback is a miss, the
        // window accuracy pins to zero
        let mut c = Client::connect(addr);
        for i in 0..8 {
            let row = fmt_row(split.test.x.row(i));
            let pred = c.ask(&format!("predict {row}"));
            assert!(pred.starts_with("ok "), "{pred}");
            let label: f64 =
                pred.split_ascii_whitespace().nth(1).unwrap().parse().unwrap();
            let wrong = if label > 0.0 { "-1" } else { "+1" };
            let fb = c.ask(&format!("feedback {wrong} {row}"));
            assert!(fb.starts_with("ok miss"), "{fb}");
        }
        let status = c.ask("fleet-status");
        assert!(status.contains("acc=0.0000"), "{status}");

        let outs = ctl
            .maybe_auto_rollback("champ", 0.9)
            .expect("degraded window must trigger the rollback");
        assert_eq!(outs[0].result, Ok(1));
        let status = c.ask("fleet-status");
        assert!(status.contains("champ@v1"), "{status}");
        assert_eq!(c.ask("shutdown"), "ok bye");
    });
    let _ = std::fs::remove_dir_all(&dir);
}

// --------------------------------- acceptance: concurrent router parity

/// Ask every key once, `clients` concurrent connections in parallel,
/// and require all clients to observe identical per-key replies.
/// Returns the (key-ordered) reply vector.
fn concurrent_keyed_replies(
    router: SocketAddr,
    keys: &[String],
    q: &str,
    clients: usize,
) -> Vec<String> {
    let all: Vec<Vec<String>> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|_| {
                s.spawn(move || {
                    let mut c = Client::connect(router);
                    keys.iter()
                        .map(|k| c.ask(&format!("decision key={k} {q}")))
                        .collect::<Vec<String>>()
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for replies in &all {
        for r in replies {
            assert!(r.starts_with("ok "), "{r}");
        }
        assert_eq!(replies, &all[0], "two concurrent clients saw different answers");
    }
    all.into_iter().next().unwrap()
}

/// Keyed answers are bit-identical across every router
/// `threads ∈ {1,2,4}` × `pool ∈ {1,2}` combination, each driven by 4
/// concurrent clients against the same 2-replica fleet.  The ring
/// assignment is a pure function of (seed, endpoints, vnodes), the
/// replicas serve the same deterministic model, and neither worker
/// scheduling nor link multiplexing may leak into a reply byte.
#[test]
fn keyed_answers_bit_identical_across_threads_and_pool_sizes() {
    let (model, split) = trained();
    let d0 = scratch("parity0");
    let d1 = scratch("parity1");
    let (l0, a0) = bind();
    let (l1, a1) = bind();
    let eps = vec![a0.to_string(), a1.to_string()];
    std::thread::scope(|s| {
        s.spawn(|| replica_serve(l0, &d0));
        s.spawn(|| replica_serve(l1, &d1));
        let mut ctl = Controller::new(eps.clone(), Duration::from_secs(10));
        for o in ctl.push(&wrap(1, &model), true) {
            assert_eq!(o.result, Ok(1), "{}", o.endpoint);
        }

        let q = fmt_row(split.test.x.row(0));
        let keys: Vec<String> = (0..24).map(|k| format!("user-{k}")).collect();
        let mut baseline: Option<Vec<String>> = None;
        for threads in [1usize, 2, 4] {
            for pool in [1usize, 2] {
                let (lr, ar) = bind();
                let ropts = RouterOptions {
                    seed: 42,
                    vnodes: 64,
                    timeout: Duration::from_secs(10),
                    probe_every: Duration::from_secs(600),
                    pool,
                    threads,
                };
                let reps = eps.clone();
                let rh = s.spawn(move || run_router(lr, reps, &ropts).unwrap());
                let replies = concurrent_keyed_replies(ar, &keys, &q, 4);
                match &baseline {
                    None => baseline = Some(replies),
                    Some(b) => assert_eq!(
                        b, &replies,
                        "threads={threads} pool={pool} changed a keyed answer"
                    ),
                }
                assert_eq!(Client::connect(ar).ask("shutdown"), "ok bye");
                let report = rh.join().unwrap();
                assert!(report.forwarded >= 96, "forwarded {}", report.forwarded);
                assert_eq!(report.replica_dead, 0, "no replica may die in this test");
            }
        }
        assert_eq!(Client::connect(a0).ask("shutdown"), "ok bye");
        assert_eq!(Client::connect(a1).ask("shutdown"), "ok bye");
    });
    let _ = std::fs::remove_dir_all(&d0);
    let _ = std::fs::remove_dir_all(&d1);
}

// ------------------------------------- acceptance: pooled link reuse

/// After warmup the pool serves every forward from existing links:
/// the router's dialed-link count (counted like `worker_spawns`) stays
/// bounded by `replicas × pool` across hundreds of forwards from
/// concurrent clients — no per-forward reconnects.
#[test]
fn pooled_links_are_reused_across_forwards() {
    let (model, split) = trained();
    let d0 = scratch("reuse0");
    let d1 = scratch("reuse1");
    let (l0, a0) = bind();
    let (l1, a1) = bind();
    let (lr, ar) = bind();
    let eps = vec![a0.to_string(), a1.to_string()];
    std::thread::scope(|s| {
        s.spawn(|| replica_serve(l0, &d0));
        s.spawn(|| replica_serve(l1, &d1));
        let ropts = RouterOptions {
            seed: 42,
            vnodes: 64,
            timeout: Duration::from_secs(10),
            probe_every: Duration::from_secs(600),
            pool: 2,
            threads: 0,
        };
        let reps = eps.clone();
        let rh = s.spawn(move || run_router(lr, reps, &ropts).unwrap());
        let mut ctl = Controller::new(eps.clone(), Duration::from_secs(10));
        for o in ctl.push(&wrap(1, &model), true) {
            assert_eq!(o.result, Ok(1), "{}", o.endpoint);
        }

        let q = fmt_row(split.test.x.row(0));
        let keys: Vec<String> = (0..32).map(|k| format!("user-{k}")).collect();
        // two bursts of 4 concurrent clients: the second burst must be
        // served entirely from links the first one opened
        let first = concurrent_keyed_replies(ar, &keys, &q, 4);
        let second = concurrent_keyed_replies(ar, &keys, &q, 4);
        assert_eq!(first, second);

        // telemetry agrees before shutdown: the router-stats verb is
        // answered locally and exposes the same counters
        let stats = Client::connect(ar).ask("router-stats");
        assert!(stats.starts_with("ok router "), "{stats}");
        assert!(stats.contains("forwards=256"), "{stats}");
        assert!(stats.contains(" dead=0 "), "{stats}");

        assert_eq!(Client::connect(ar).ask("shutdown"), "ok bye");
        let report = rh.join().unwrap();
        assert_eq!(report.forwarded, 256, "2 bursts x 4 clients x 32 keys");
        assert!(
            report.links_opened <= 4,
            "a 2-replica x pool-2 router dialed {} links for {} forwards",
            report.links_opened,
            report.forwarded,
        );
        assert_eq!(report.replica_dead, 0);
        assert_eq!(Client::connect(a0).ask("shutdown"), "ok bye");
        assert_eq!(Client::connect(a1).ask("shutdown"), "ok bye");
    });
    let _ = std::fs::remove_dir_all(&d0);
    let _ = std::fs::remove_dir_all(&d1);
}

// --------------------------------- acceptance: broken link != dead replica

/// A replica-side idle reap closes the router's pooled links between
/// bursts.  The next burst hits stale sockets: the router must discard
/// each broken link, retry over a fresh one to the *same* replica, and
/// answer bit-identically — without ever marking the replica dead.
#[test]
fn broken_pooled_link_is_recycled_without_marking_replica_dead() {
    let (model, split) = trained();
    let d0 = scratch("stale0");
    let d1 = scratch("stale1");
    let (l0, a0) = bind();
    let (l1, a1) = bind();
    let (lr, ar) = bind();
    let eps = vec![a0.to_string(), a1.to_string()];
    std::thread::scope(|s| {
        // replicas reap connections idle for >500ms — the router's
        // pooled links go stale during the sleep below
        let short_idle =
            ServeOptions { idle_timeout: Duration::from_millis(500), ..ServeOptions::default() };
        let (so0, so1) = (short_idle.clone(), short_idle);
        s.spawn(move || replica_serve_opts(l0, &d0, so0));
        s.spawn(move || replica_serve_opts(l1, &d1, so1));
        let ropts = RouterOptions {
            seed: 42,
            vnodes: 64,
            timeout: Duration::from_secs(10),
            probe_every: Duration::from_secs(600),
            pool: 2,
            threads: 0,
        };
        let reps = eps.clone();
        let rh = s.spawn(move || run_router(lr, reps, &ropts).unwrap());
        let mut ctl = Controller::new(eps.clone(), Duration::from_secs(10));
        for o in ctl.push(&wrap(1, &model), true) {
            assert_eq!(o.result, Ok(1), "{}", o.endpoint);
        }

        let q = fmt_row(split.test.x.row(0));
        let keys: Vec<String> = (0..24).map(|k| format!("user-{k}")).collect();
        let mut client = Client::connect(ar);
        let before: Vec<String> =
            keys.iter().map(|k| client.ask(&format!("decision key={k} {q}"))).collect();
        for r in &before {
            assert!(r.starts_with("ok "), "{r}");
        }

        // let both replicas reap every pooled link mid-"burst"
        std::thread::sleep(Duration::from_millis(1200));

        let after: Vec<String> =
            keys.iter().map(|k| client.ask(&format!("decision key={k} {q}"))).collect();
        assert_eq!(before, after, "a recycled link changed an answer");

        assert_eq!(client.ask("shutdown"), "ok bye");
        let report = rh.join().unwrap();
        assert_eq!(report.forwarded, 48, "every request must be answered");
        assert!(report.retried >= 1, "stale links must surface as link retries");
        assert_eq!(
            report.replica_dead, 0,
            "a broken pooled link must never mark the replica dead"
        );
        assert_eq!(Client::connect(a0).ask("shutdown"), "ok bye");
        assert_eq!(Client::connect(a1).ask("shutdown"), "ok bye");
    });
    let _ = std::fs::remove_dir_all(&d0);
    let _ = std::fs::remove_dir_all(&d1);
}

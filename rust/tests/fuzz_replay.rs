//! Fuzz-replay hardening (ISSUE 6): every surface that parses
//! untrusted bytes is replayed against the checked-in corpus under
//! `fuzz/corpus/` and against seeded deterministic mutations of the
//! valid seeds.  The pinned contract, for every input:
//!
//! * the parser returns `Ok` or a **typed error** — never a panic;
//! * corpus files named `ok_*` parse successfully, `bad_*` are
//!   rejected;
//! * emit→parse round trips are fixed points (`parse(emit(x))`
//!   re-emits byte-identically);
//! * a live `BatchEngine` survives token-soup protocol traffic and
//!   still answers correctly afterwards.
//!
//! Everything here runs in plain `cargo test` — no nightly, no
//! cargo-fuzz; mutations are driven by the repo's own `Xoshiro256`, so
//! a failure reproduces from the seed printed in the assert message.

use std::fs;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::Path;

use mmbsgd::config::{ServeConfig, TomlDoc, TrainConfig};
use mmbsgd::data::synth::{dataset, SynthSpec};
use mmbsgd::data::libsvm;
use mmbsgd::fleet::{Artifact, Provenance};
use mmbsgd::model::SvmModel;
use mmbsgd::rng::Xoshiro256;
use mmbsgd::runtime::NativeBackend;
use mmbsgd::serve::proto::parse_line;
use mmbsgd::serve::{BatchEngine, Command, ModelRegistry, ShedPolicy};
use mmbsgd::solver::{bsgd, Checkpoint, NoopObserver, TrainSession};

// ------------------------------------------------------------ corpus

/// Load one corpus directory as sorted `(file_name, contents)` pairs.
/// Fails loudly when the directory is missing or empty so the corpus
/// cannot silently rot out of the build.
fn corpus(kind: &str) -> Vec<(String, String)> {
    let dir =
        Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("fuzz").join("corpus").join(kind);
    let mut cases: Vec<(String, String)> = fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("corpus dir {}: {e}", dir.display()))
        .map(|entry| entry.expect("corpus entry").path())
        .filter(|p| p.is_file())
        .map(|p| {
            let name = p.file_name().expect("file name").to_string_lossy().into_owned();
            let text =
                fs::read_to_string(&p).unwrap_or_else(|e| panic!("read {}: {e}", p.display()));
            (name, text)
        })
        .collect();
    cases.sort();
    assert!(!cases.is_empty(), "corpus {kind} is empty");
    for (name, _) in &cases {
        assert!(
            name.starts_with("ok_") || name.starts_with("bad_"),
            "corpus {kind}/{name}: files must be named ok_* or bad_*"
        );
    }
    cases
}

/// Replay a corpus through a parser: no input may panic, `ok_*` must
/// parse, `bad_*` must be rejected with a typed error.
fn replay(kind: &str, parse: impl Fn(&str) -> Result<(), String>) {
    for (name, text) in corpus(kind) {
        let result = catch_unwind(AssertUnwindSafe(|| parse(&text)))
            .unwrap_or_else(|_| panic!("{kind}/{name}: parser PANICKED"));
        if name.starts_with("ok_") {
            assert!(result.is_ok(), "{kind}/{name}: expected Ok, got: {}", result.unwrap_err());
        } else {
            assert!(result.is_err(), "{kind}/{name}: malformed input parsed cleanly");
        }
    }
}

fn parse_checkpoint(text: &str) -> Result<(), String> {
    Checkpoint::parse(text).map(|_| ()).map_err(|e| e.to_string())
}

fn parse_model(text: &str) -> Result<(), String> {
    SvmModel::from_text(text).map(|_| ()).map_err(|e| e.to_string())
}

/// The full config pipeline: TOML-subset parse, overlay onto both
/// config structs, validate both — a corpus file is "ok" only when a
/// CLI run with it would actually start.
fn parse_toml_pipeline(text: &str) -> Result<(), String> {
    let doc = TomlDoc::parse(text).map_err(|e| e.to_string())?;
    let mut train = TrainConfig::default();
    train.apply_toml(&doc).map_err(|e| e.to_string())?;
    train.validate().map_err(|e| e.to_string())?;
    let mut serve = ServeConfig::default();
    serve.apply_toml(&doc).map_err(|e| e.to_string())?;
    serve.validate().map_err(|e| e.to_string())?;
    Ok(())
}

fn parse_libsvm(text: &str) -> Result<(), String> {
    libsvm::parse(text, None).map(|_| ()).map_err(|e| e.to_string())
}

/// The HTTP front end's request gate: head framing + policing + body
/// slicing against the declared Content-Length, at the production
/// default body cap — a corpus file is "ok" only when the serve loop
/// would dispatch it.
fn parse_http_request(text: &str) -> Result<(), String> {
    mmbsgd::serve::http::validate_request_text(text, 1024 * 1024)
        .map(|_| ())
        .map_err(|e| e.to_string())
}

/// The full fleet-artifact gate: manifest parse (incl. the per-section
/// checksum) plus the model/manifest cross-check — a corpus file is
/// "ok" only when a replica would actually stage-and-activate it.
fn parse_manifest(text: &str) -> Result<(), String> {
    let artifact = Artifact::parse(text).map_err(|e| e.to_string())?;
    artifact.validate_model().map(|_| ()).map_err(|e| e.to_string())
}

#[test]
fn checkpoint_corpus_replays_typed() {
    replay("checkpoint", parse_checkpoint);
}

#[test]
fn model_corpus_replays_typed() {
    replay("model", parse_model);
}

#[test]
fn toml_corpus_replays_typed() {
    replay("toml", parse_toml_pipeline);
}

#[test]
fn libsvm_corpus_replays_typed() {
    replay("libsvm", parse_libsvm);
}

/// HTTP corpus files hold one whole request per file (CRLF framing and
/// all); `ok_*` must pass the request gate, `bad_*` must answer a
/// typed `HttpError` carrying a 4xx/5xx status.
#[test]
fn http_corpus_replays_typed() {
    replay("http", parse_http_request);
    // the typed rejections carry real statuses, not just strings
    for (name, text) in corpus("http") {
        if let Err(e) = mmbsgd::serve::http::validate_request_text(&text, 1024 * 1024) {
            assert!(
                name.starts_with("bad_"),
                "http/{name}: ok_* seed rejected with {e}"
            );
            assert!((400..600).contains(&e.status), "http/{name}: status {}", e.status);
        }
    }
}

/// The `ok_*` manifest seeds carry `fnv=` checksums computed by an
/// independent implementation of the seeded-FNV + SplitMix64 digest
/// (outside this codebase), so this replay also pins
/// `durable::checksum` cross-process: any drift in the hash breaks
/// the seeds.
#[test]
fn manifest_corpus_replays_typed() {
    // the digest itself first, against independently computed goldens
    use mmbsgd::util::durable::checksum;
    assert_eq!(checksum(b""), 0x1c987589c237443a);
    assert_eq!(checksum(b"mmbsgd"), 0x0f91a5a70155131a);
    assert_eq!(checksum(b"mmbsgd-model v1\n"), 0x41915b133a2b5d5b);
    replay("manifest", parse_manifest);
}

/// Protocol corpus files hold one line per case (comments start with
/// `#`): every line of an `ok_*` file must parse, every line of a
/// `bad_*` file must answer a typed error.
#[test]
fn proto_corpus_replays_typed() {
    for (name, text) in corpus("proto") {
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() || line.starts_with('#') {
                continue;
            }
            let result = catch_unwind(AssertUnwindSafe(|| parse_line(line)))
                .unwrap_or_else(|_| panic!("proto/{name}:{}: parse_line PANICKED", i + 1));
            if name.starts_with("ok_") {
                assert!(result.is_ok(), "proto/{name}:{}: {:?}", i + 1, result.unwrap_err());
            } else {
                assert!(result.is_err(), "proto/{name}:{}: parsed cleanly", i + 1);
            }
        }
    }
    // the degenerate line is typed too
    assert!(parse_line("").is_err());
    assert!(parse_line("   ").is_err());
}

// ------------------------------------------------- mutation sweeps

/// One deterministic mutation of `seed_text`: truncation, printable
/// byte stomp, line duplication, line deletion, or line swap.  Byte
/// stomps go through `from_utf8_lossy`, so the result is always valid
/// UTF-8 (the transport layer already guarantees that to the parsers).
fn mutate(rng: &mut Xoshiro256, seed_text: &str) -> String {
    match rng.next_below(5) {
        0 => {
            let cut = rng.next_below(seed_text.len() + 1);
            let mut bytes = seed_text.as_bytes()[..cut].to_vec();
            if let Some(op) = bytes.last_mut() {
                // half the time also tear the last byte
                if rng.next_below(2) == 0 {
                    *op = b' ' + rng.next_below(95) as u8;
                }
            }
            String::from_utf8_lossy(&bytes).into_owned()
        }
        1 => {
            let mut bytes = seed_text.as_bytes().to_vec();
            if !bytes.is_empty() {
                let i = rng.next_below(bytes.len());
                bytes[i] = b' ' + rng.next_below(95) as u8;
            }
            String::from_utf8_lossy(&bytes).into_owned()
        }
        2 => {
            let mut lines: Vec<&str> = seed_text.lines().collect();
            if !lines.is_empty() {
                let i = rng.next_below(lines.len());
                lines.insert(i, lines[i]);
            }
            lines.join("\n") + "\n"
        }
        3 => {
            let mut lines: Vec<&str> = seed_text.lines().collect();
            if !lines.is_empty() {
                lines.remove(rng.next_below(lines.len()));
            }
            lines.join("\n") + "\n"
        }
        _ => {
            let mut lines: Vec<&str> = seed_text.lines().collect();
            if lines.len() >= 2 {
                let i = rng.next_below(lines.len());
                let j = rng.next_below(lines.len());
                lines.swap(i, j);
            }
            lines.join("\n") + "\n"
        }
    }
}

/// Drive `rounds` seeded mutations of every `ok_*` seed in a corpus
/// through a parser; the parser may accept or reject each mutant, but
/// it must never panic.
fn mutation_sweep(kind: &str, rounds: usize, parse: impl Fn(&str) -> Result<(), String>) {
    let seeds: Vec<(String, String)> =
        corpus(kind).into_iter().filter(|(n, _)| n.starts_with("ok_")).collect();
    assert!(!seeds.is_empty(), "corpus {kind} has no ok_* seeds to mutate");
    for (name, seed_text) in seeds {
        let mut rng = Xoshiro256::new(0xF022 + kind.len() as u64);
        for round in 0..rounds {
            let mutant = mutate(&mut rng, &seed_text);
            catch_unwind(AssertUnwindSafe(|| {
                let _ = parse(&mutant);
            }))
            .unwrap_or_else(|_| {
                panic!("{kind}/{name} mutation round {round}: parser PANICKED on:\n{mutant}")
            });
        }
    }
}

#[test]
fn checkpoint_mutations_never_panic() {
    mutation_sweep("checkpoint", 300, parse_checkpoint);
    // also sweep a real emitted blob, which exercises deeper sections
    // (SV block, pending indices, history) than the minimal seed
    let blob = trained_checkpoint_blob();
    let mut rng = Xoshiro256::new(0xB10B);
    for round in 0..300 {
        let mutant = mutate(&mut rng, &blob);
        catch_unwind(AssertUnwindSafe(|| {
            let _ = Checkpoint::parse(&mutant);
        }))
        .unwrap_or_else(|_| panic!("emitted-blob mutation round {round} PANICKED:\n{mutant}"));
    }
}

#[test]
fn model_mutations_never_panic() {
    mutation_sweep("model", 300, parse_model);
}

#[test]
fn toml_mutations_never_panic() {
    mutation_sweep("toml", 300, parse_toml_pipeline);
}

#[test]
fn libsvm_mutations_never_panic() {
    mutation_sweep("libsvm", 300, parse_libsvm);
}

#[test]
fn manifest_mutations_never_panic() {
    mutation_sweep("manifest", 300, parse_manifest);
}

#[test]
fn http_mutations_never_panic() {
    mutation_sweep("http", 300, parse_http_request);
}

// ------------------------------------------------- round-trip fixed points

fn tiny_cfg() -> TrainConfig {
    TrainConfig {
        lambda: 1e-3,
        gamma: 2.0,
        budget: 24,
        mergees: 3,
        seed: 77,
        ..TrainConfig::default()
    }
}

/// A checkpoint taken mid-epoch from a real training run, so the SV
/// block, pending remainder, and history sections are all populated.
fn trained_checkpoint_blob() -> String {
    let split = dataset(&SynthSpec::ijcnn_like(0.01), 3);
    let mut be = NativeBackend::new();
    let mut sess = TrainSession::new(tiny_cfg(), &mut be).expect("valid config");
    let done = sess.run_epoch(&split.train, None, &mut NoopObserver, 41).expect("train");
    assert!(!done, "want a mid-epoch checkpoint");
    sess.checkpoint()
}

/// `parse(emit(x))` must re-emit byte-identically: the checkpoint text
/// format is a fixed point, so a resume of a resume can never drift.
#[test]
fn checkpoint_roundtrip_is_a_fixed_point() {
    let blob = trained_checkpoint_blob();
    let ck = Checkpoint::parse(&blob).expect("own emitter output parses");
    let mut be = NativeBackend::new();
    let resumed = ck.into_session(&mut be).expect("attach");
    assert_eq!(resumed.checkpoint(), blob, "emit→parse→emit drifted");
}

#[test]
fn model_text_roundtrip_is_a_fixed_point() {
    let split = dataset(&SynthSpec::ijcnn_like(0.01), 3);
    let model = bsgd::train(&split.train, &tiny_cfg()).expect("train").model;
    let text = model.to_text();
    let reparsed = SvmModel::from_text(&text).expect("own emitter output parses");
    assert_eq!(reparsed.to_text(), text, "emit→parse→emit drifted");
}

/// Artifact bundles are a fixed point too: wrap→emit→parse→emit is
/// byte-identical, so a re-packaged pushed bundle can never drift.
#[test]
fn artifact_text_roundtrip_is_a_fixed_point() {
    let split = dataset(&SynthSpec::ijcnn_like(0.01), 3);
    let model = bsgd::train(&split.train, &tiny_cfg()).expect("train").model;
    let cfg = tiny_cfg();
    let a = Artifact::wrap("champ", 9, &model, Provenance::from_config(&cfg), "lut", "auto")
        .expect("wrap");
    let text = a.to_text();
    let b = Artifact::parse(&text).expect("own emitter output parses");
    assert_eq!(b.to_text(), text, "wrap→emit→parse→emit drifted");
    b.validate_model().expect("reparsed bundle validates");
}

// ------------------------------------------------- live-engine fuzz

/// Token-soup protocol fuzz against a live engine: random token lines
/// (seeded, reproducible) are parsed and — when they parse — submitted
/// and flushed.  The engine must neither panic nor wedge: after the
/// storm it still answers a well-formed query correctly.
#[test]
fn protocol_token_soup_against_live_engine() {
    let split = dataset(&SynthSpec::ijcnn_like(0.01), 2);
    let model = bsgd::train(&split.train, &tiny_cfg()).expect("train").model;
    let dim = model.svs.dim();
    let mut reg = ModelRegistry::new(Box::new(NativeBackend::new()), 11);
    reg.insert("m", model).expect("insert");
    let mut eng = BatchEngine::new(16, 4096, ShedPolicy::Reject);

    const TOKENS: &[&str] = &[
        "predict", "decision", "feedback", "stats", "swap-model", "shutdown", "key=u1", "key=",
        "+1", "-1", "0.5", "-0.25", "1e-3", "1e999", "nan", "inf", "zebra", ":", ";", "0",
        "18446744073709551615", "-0", "#", "key=predict", "\u{1F980}",
    ];
    let mut rng = Xoshiro256::new(0x50D4);
    for round in 0..400 {
        let n = rng.next_below(8);
        let mut line = String::new();
        for i in 0..n {
            if i > 0 {
                line.push(' ');
            }
            line.push_str(TOKENS[rng.next_below(TOKENS.len())]);
        }
        let parsed = catch_unwind(AssertUnwindSafe(|| parse_line(&line)))
            .unwrap_or_else(|_| panic!("round {round}: parse_line PANICKED on {line:?}"));
        match parsed {
            Ok(Command::Predict { key, x }) | Ok(Command::Decision { key, x }) => {
                // wrong-dimension submissions must answer a typed error
                // from flush, not crash the batch
                let _ = eng.submit(&reg, key.as_deref(), x);
            }
            _ => {}
        }
        if round % 16 == 15 {
            // answers are Ok or typed errors, both fine — flushing
            // mixed garbage must not panic
            let _ = eng.flush(&mut reg);
        }
    }
    let _ = eng.flush(&mut reg);
    assert_eq!(eng.queued(), 0, "engine wedged");

    // the engine still serves a correct well-formed request
    let line = {
        let mut s = String::from("decision key=survivor");
        for v in split.test.x.row(0) {
            s.push_str(&format!(" {v}"));
        }
        s
    };
    let Command::Decision { key, x } = parse_line(&line).expect("well-formed") else {
        panic!("expected a decision command");
    };
    assert_eq!(x.len(), dim);
    let id = eng.submit(&reg, key.as_deref(), x).expect("submit");
    let res = eng.flush(&mut reg);
    assert_eq!(res.len(), 1);
    assert_eq!(res[0].0, id);
    assert!(res[0].1.is_ok(), "post-storm request failed: {:?}", res[0].1);
}

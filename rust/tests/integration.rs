//! Cross-module integration tests: data pipeline → solver → model
//! persistence → coordinator grid, plus the theory-facing invariants
//! that span modules.

use mmbsgd::budget::{Budget, MaintenanceKind};
use mmbsgd::config::TrainConfig;
use mmbsgd::coordinator::{run_grid, RunSpec};
use mmbsgd::data::synth::{dataset, SynthSpec};
use mmbsgd::data::{libsvm, split};
use mmbsgd::model::{SvStore, SvmModel};
use mmbsgd::rng::Xoshiro256;
use mmbsgd::runtime::NativeBackend;
use mmbsgd::solver::{bsgd, pegasos, smo};

fn tiny_cfg(spec: &SynthSpec, n: usize, budget: usize, m: usize) -> TrainConfig {
    TrainConfig {
        lambda: TrainConfig::lambda_from_c(spec.c, n),
        gamma: spec.gamma,
        budget,
        mergees: m,
        epochs: 1,
        seed: 3,
        ..TrainConfig::default()
    }
}

#[test]
fn libsvm_roundtrip_preserves_training_behaviour() {
    // synth → write LIBSVM text → parse → identical training outcome
    let split_ = dataset(&SynthSpec::ijcnn_like(0.01), 1);
    let text = libsvm::write(&split_.train);
    let dir = std::env::temp_dir().join("mmbsgd_test_libsvm.txt");
    std::fs::write(&dir, &text).unwrap();
    let reparsed = libsvm::load(&dir, Some(split_.train.dim())).unwrap();
    assert_eq!(reparsed.len(), split_.train.len());
    let spec = SynthSpec::ijcnn_like(0.01);
    let cfg = tiny_cfg(&spec, split_.train.len(), 32, 3);
    let a = bsgd::train(&split_.train, &cfg).unwrap();
    let b = bsgd::train(&reparsed, &cfg).unwrap();
    assert_eq!(a.margin_violations, b.margin_violations);
    assert_eq!(a.model.svs.len(), b.model.svs.len());
    std::fs::remove_file(&dir).ok();
}

#[test]
fn model_survives_save_load_with_identical_predictions() {
    let split_ = dataset(&SynthSpec::phishing_like(0.02), 2);
    let spec = SynthSpec::phishing_like(0.02);
    let cfg = tiny_cfg(&spec, split_.train.len(), 48, 4);
    let out = bsgd::train(&split_.train, &cfg).unwrap();
    let path = std::env::temp_dir().join("mmbsgd_test_model.txt");
    out.model.save(&path).unwrap();
    let loaded = SvmModel::load(&path).unwrap();
    for i in 0..split_.test.len().min(50) {
        let x = split_.test.sample(i).x;
        let (a, b) = (out.model.decision(x), loaded.decision(x));
        assert!(
            (a - b).abs() < 1e-5 * (1.0 + a.abs()),
            "prediction drift after save/load: {a} vs {b}"
        );
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn theorem1_gradient_error_shrinks_with_budget() {
    // Theorem 1: the regret bound degrades with the mean weight
    // degradation. Larger budgets must yield smaller mean wd per event.
    let split_ = dataset(&SynthSpec::adult_like(0.02), 4);
    let spec = SynthSpec::adult_like(0.02);
    let mut wds = Vec::new();
    for budget in [16usize, 64, 160] {
        let cfg = tiny_cfg(&spec, split_.train.len(), budget, 3);
        let out = bsgd::train(&split_.train, &cfg).unwrap();
        if out.maintenance_events > 0 {
            wds.push(out.mean_weight_degradation);
        }
    }
    assert!(wds.len() >= 2, "need at least two budgets that trigger maintenance");
    assert!(
        wds.windows(2).all(|w| w[1] <= w[0] * 1.5),
        "mean wd should not grow with budget: {wds:?}"
    );
    assert!(
        wds.last().unwrap() < &(wds[0] * 0.9),
        "largest budget should merge more cheaply: {wds:?}"
    );
}

#[test]
fn multimerge_speedup_and_event_reduction() {
    // The core paper claim, end to end: multi-merge reduces maintenance
    // events by ~(M-1)x and does not destroy accuracy.
    let split_ = dataset(&SynthSpec::ijcnn_like(0.04), 5);
    let spec = SynthSpec::ijcnn_like(0.04);
    let cfg2 = tiny_cfg(&spec, split_.train.len(), 20, 2);
    let cfg5 = tiny_cfg(&spec, split_.train.len(), 20, 5);
    let out2 = bsgd::train(&split_.train, &cfg2).unwrap();
    let out5 = bsgd::train(&split_.train, &cfg5).unwrap();
    let acc2 = out2.model.accuracy(&split_.test);
    let acc5 = out5.model.accuracy(&split_.test);
    // Ideal reduction is (M-1)x = 4x; the trajectory change (merged SVs
    // absorb future violators differently) erodes it — require > 2x.
    assert!(
        out5.maintenance_events * 2 < out2.maintenance_events,
        "events: M=5 {} vs M=2 {}",
        out5.maintenance_events,
        out2.maintenance_events
    );
    assert!(
        acc5 > acc2 - 0.05,
        "M=5 accuracy {acc5} collapsed vs M=2 {acc2}"
    );
}

#[test]
fn smo_and_bsgd_agree_on_easy_data() {
    let split_ = dataset(&SynthSpec::skin_like(0.002), 6);
    let spec = SynthSpec::skin_like(0.002);
    let (smo_model, stats) = smo::train(
        &split_.train,
        &smo::SmoParams { c: spec.c, gamma: spec.gamma, ..Default::default() },
    );
    assert!(stats.converged);
    let smo_acc = smo_model.accuracy(&split_.test);
    let cfg = tiny_cfg(&spec, split_.train.len(), 64, 3);
    let out = bsgd::train(&split_.train, &cfg).unwrap();
    let bsgd_acc = out.model.accuracy(&split_.test);
    assert!(smo_acc > 0.9, "smo {smo_acc}");
    assert!(bsgd_acc > smo_acc - 0.1, "bsgd {bsgd_acc} too far below smo {smo_acc}");
}

#[test]
fn pegasos_is_bsgd_upper_envelope() {
    // ADULT twin: noisy, so the unbudgeted model accumulates many SVs.
    let split_ = dataset(&SynthSpec::adult_like(0.02), 7);
    let spec = SynthSpec::adult_like(0.02);
    let cfg = tiny_cfg(&spec, split_.train.len(), 32, 2);
    let unb = pegasos::train(&split_.train, &cfg).unwrap();
    assert_eq!(unb.maintenance_events, 0);
    assert!(unb.model.svs.len() >= 32, "unbudgeted model should exceed the budget");
}

#[test]
fn coordinator_grid_runs_mixed_strategies() {
    let spec = SynthSpec::ijcnn_like(0.01);
    let mut specs = Vec::new();
    for (i, kind) in [
        MaintenanceKind::Removal,
        MaintenanceKind::Merge { m: 2 },
        MaintenanceKind::Merge { m: 5 },
        MaintenanceKind::MergeGd { m: 3 },
    ]
    .into_iter()
    .enumerate()
    {
        let mut cfg = tiny_cfg(&spec, 1, 24, 3);
        cfg.cost_c = Some(spec.c); // pending C, resolved by the coordinator
        cfg.maintenance = Some(kind);
        specs.push(RunSpec {
            name: format!("grid{i}"),
            data: spec.clone(),
            data_seed: 1,
            cfg,
        });
    }
    let results = run_grid(specs, 2);
    for r in results {
        let r = r.unwrap();
        assert!(r.test_accuracy > 0.5, "{}: acc {}", r.name, r.test_accuracy);
        assert!(r.n_svs <= 24);
    }
}

#[test]
fn budget_struct_accumulates_across_events() {
    let mut svs = SvStore::new(2);
    let mut rng = Xoshiro256::new(8);
    let mut budget = Budget::new(8, MaintenanceKind::Merge { m: 3 });
    let mut be = NativeBackend::new();
    for _ in 0..30 {
        let x = [rng.next_gaussian() as f32, rng.next_gaussian() as f32];
        svs.push(&x, 0.1 + rng.next_f64());
        budget.enforce(&mut svs, 1.0, &mut be);
        assert!(svs.len() <= 8);
    }
    assert!(budget.events >= 10);
    assert!(budget.total_wd > 0.0);
    assert!(budget.mean_wd() > 0.0);
    assert_eq!(budget.total_removed, budget.events * 2); // M-1 = 2 per event
}

#[test]
fn stratified_subsample_feeds_smo_reference() {
    let split_ = dataset(&SynthSpec::adult_like(0.05), 9);
    let sub = split::stratified_subsample(&split_.train, 300, 1);
    assert_eq!(sub.len(), 300);
    let frac_full = split_.train.positive_fraction();
    let frac_sub = sub.positive_fraction();
    assert!((frac_full - frac_sub).abs() < 0.05);
}

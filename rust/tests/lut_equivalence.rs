//! LUT merge scorer vs the exact golden-section reference.
//!
//! Three layers of evidence that the precomputed table (arXiv
//! 1806.10180) loses nothing:
//! 1. a property sweep over random `(a_i, a_j, c)` pinning the
//!    LUT-scored `(wd, h, a_z)` to `merge_pair_params` within
//!    interpolation tolerance,
//! 2. per-lane parity of full `merge_scores` passes, and
//! 3. end-to-end training on the synthetic ijcnn-like split: `lut` and
//!    `exact` modes must land within 0.5% test accuracy of each other.

use mmbsgd::budget::golden::{self, GS_ITERS};
use mmbsgd::budget::{MergeLut, MergeScoreMode};
use mmbsgd::config::TrainConfig;
use mmbsgd::data::synth::{dataset, SynthSpec};
use mmbsgd::kernel::EXP_NEG_CUTOFF;
use mmbsgd::rng::Xoshiro256;
use mmbsgd::runtime::{Backend, NativeBackend};
use mmbsgd::solver::bsgd;

#[test]
fn prop_lut_matches_exact_pair_params() {
    let lut = MergeLut::global();
    let mut rng = Xoshiro256::new(0x1806_1018);
    let mut checked = 0u32;
    for case in 0..8000 {
        let a_i = (rng.next_f64() - 0.5) * 4.0;
        let a_j = (rng.next_f64() - 0.5) * 4.0;
        if a_i.abs() < 1e-6 || a_j.abs() < 1e-6 {
            continue;
        }
        // cover the whole table domain plus the far-pair regime
        let c = rng.next_f64() * (EXP_NEG_CUTOFF * 1.5);
        let ex = golden::merge_pair_params(a_i, a_j, c, GS_ITERS);
        let lu = lut.merge_pair_params(a_i, a_j, c);
        let norm2 = a_i * a_i + a_j * a_j;
        assert!(
            (lu.wd - ex.wd).abs() <= 1e-4 * norm2 + 1e-9,
            "case {case}: wd {} vs exact {} (a_i={a_i}, a_j={a_j}, c={c})",
            lu.wd,
            ex.wd
        );
        assert!(
            (lu.a_z.abs() - ex.a_z.abs()).abs() <= 1e-4 * norm2.sqrt() + 1e-9,
            "case {case}: a_z {} vs exact {} (a_i={a_i}, a_j={a_j}, c={c})",
            lu.a_z,
            ex.a_z
        );
        assert!(
            (lu.h - ex.h).abs() <= 0.05,
            "case {case}: h {} vs exact {} (a_i={a_i}, a_j={a_j}, c={c})",
            lu.h,
            ex.h
        );
        checked += 1;
    }
    assert!(checked > 6000, "sweep degenerated: only {checked} cases");
}

#[test]
fn merge_scores_lane_parity() {
    let mut rng = Xoshiro256::new(99);
    for &(b, d, gamma) in &[(32usize, 3usize, 1.2f64), (96, 16, 0.4)] {
        let mut svs = mmbsgd::model::SvStore::new(d);
        for _ in 0..b {
            let x: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
            let mut a = 0.05 + rng.next_f64();
            if rng.next_f64() < 0.5 {
                a = -a;
            }
            svs.push(&x, a);
        }
        let i = svs.min_abs_alpha().unwrap();
        let exact = NativeBackend::exact().merge_scores(&svs, gamma, i);
        let lut = NativeBackend::new().merge_scores(&svs, gamma, i);
        assert!(exact.wd[i].is_infinite() && lut.wd[i].is_infinite());
        for j in 0..b {
            if j == i {
                continue;
            }
            let norm2 = svs.alpha(i).powi(2) + svs.alpha(j).powi(2);
            assert!(
                (exact.wd[j] - lut.wd[j]).abs() <= 1e-4 * norm2 + 1e-9,
                "B={b} lane {j}: wd {} vs {}",
                lut.wd[j],
                exact.wd[j]
            );
            assert_eq!(exact.d2[j], lut.d2[j], "d2 must be identical (same cache)");
        }
    }
}

#[test]
fn lut_and_exact_training_accuracy_within_half_percent() {
    // The acceptance gate: same stream, same hyperparameters, only the
    // merge scorer differs.  Near-tie partner selections can diverge the
    // trajectories, so accuracy (not the SV set) is the contract.
    let split = dataset(&SynthSpec::ijcnn_like(0.02), 11);
    let spec = SynthSpec::ijcnn_like(0.02);
    let mk = |mode: MergeScoreMode| TrainConfig {
        lambda: TrainConfig::lambda_from_c(spec.c, split.train.len()),
        gamma: spec.gamma,
        budget: 48,
        mergees: 4,
        epochs: 1,
        seed: 7,
        merge_score_mode: mode,
        ..TrainConfig::default()
    };
    let out_exact = bsgd::train(&split.train, &mk(MergeScoreMode::Exact)).unwrap();
    let out_lut = bsgd::train(&split.train, &mk(MergeScoreMode::Lut)).unwrap();
    assert!(out_exact.maintenance_events > 0, "budget never hit — test is vacuous");
    let acc_exact = out_exact.model.accuracy(&split.test);
    let acc_lut = out_lut.model.accuracy(&split.test);
    assert!(
        (acc_exact - acc_lut).abs() < 0.005,
        "lut accuracy {acc_lut} vs exact {acc_exact} diverged >0.5%"
    );
    // mode is recorded in the model provenance string
    assert!(out_lut.model.meta.contains("score=lut"), "meta: {}", out_lut.model.meta);
    assert!(out_exact.model.meta.contains("score=exact"));
}

#[test]
fn config_mode_reaches_backend_through_train_full() {
    let split = dataset(&SynthSpec::ijcnn_like(0.01), 3);
    let spec = SynthSpec::ijcnn_like(0.01);
    let mut cfg = TrainConfig {
        lambda: TrainConfig::lambda_from_c(spec.c, split.train.len()),
        gamma: spec.gamma,
        budget: 16,
        mergees: 2,
        seed: 1,
        merge_score_mode: MergeScoreMode::Exact,
        ..TrainConfig::default()
    };
    // backend constructed in Lut mode; train_full must switch it.
    let mut be = NativeBackend::new();
    let _ = bsgd::train_full(
        &split.train,
        &cfg,
        &mut be,
        None,
        &mut mmbsgd::solver::NoopObserver,
    );
    assert_eq!(be.mode(), MergeScoreMode::Exact);
    cfg.merge_score_mode = MergeScoreMode::Lut;
    let _ = bsgd::train_full(
        &split.train,
        &cfg,
        &mut be,
        None,
        &mut mmbsgd::solver::NoopObserver,
    );
    assert_eq!(be.mode(), MergeScoreMode::Lut);
}

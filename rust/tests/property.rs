//! Property-based tests (first-party harness — proptest is not vendored
//! in this offline image): randomized sweeps over budget-maintenance and
//! solver invariants with seed reporting on failure.

use mmbsgd::budget::golden::{self, GS_ITERS};
use mmbsgd::budget::{Budget, MaintenanceKind};
use mmbsgd::config::TrainConfig;
use mmbsgd::data::synth::{dataset, SynthSpec};
use mmbsgd::kernel::{sq_dist, Gaussian, Kernel};
use mmbsgd::model::SvStore;
use mmbsgd::rng::Xoshiro256;
use mmbsgd::runtime::{exact_multi_wd, Backend, NativeBackend};
use mmbsgd::solver::bsgd;

/// Tiny property harness: run `f` for `cases` random seeds; on failure
/// report the seed so the case replays deterministically.
fn forall(name: &str, cases: u64, f: impl Fn(&mut Xoshiro256)) {
    for case in 0..cases {
        let seed = 0xC0FFEE ^ (case * 0x9E37_79B9);
        let mut rng = Xoshiro256::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = result {
            panic!("property '{name}' failed on case {case} (seed {seed:#x}): {e:?}");
        }
    }
}

fn random_store(rng: &mut Xoshiro256, b: usize, d: usize, mixed: bool) -> SvStore {
    let mut s = SvStore::new(d);
    for _ in 0..b {
        let x: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        let mut a = 0.05 + rng.next_f64();
        if mixed && rng.next_f64() < 0.5 {
            a = -a;
        }
        s.push(&x, a);
    }
    s
}

#[test]
fn prop_binary_merge_degradation_bounds() {
    // 0 <= wd <= ||a_i φ_i + a_j φ_j||², and wd <= min(a_i², a_j²)(1−k²)
    // (merging is at least as good as remove+project of either point).
    forall("binary merge wd bounds", 300, |rng| {
        let a_i = (rng.next_f64() - 0.3) * 2.0;
        let a_j = (rng.next_f64() - 0.3) * 2.0;
        if a_i == 0.0 || a_j == 0.0 {
            return;
        }
        let c = rng.next_f64() * 20.0 + 1e-6;
        let pm = golden::merge_pair_params(a_i, a_j, c, GS_ITERS);
        let k = (-c).exp();
        let norm2 = a_i * a_i + a_j * a_j + 2.0 * a_i * a_j * k;
        assert!(pm.wd >= -1e-9, "negative wd {}", pm.wd);
        assert!(pm.wd <= norm2 + 1e-9, "wd {} above total norm {norm2}", pm.wd);
        let endpoint = a_i.abs().min(a_j.abs()).powi(2) * (1.0 - k * k);
        assert!(
            pm.wd <= endpoint + 1e-7,
            "wd {} worse than endpoint bound {endpoint} (a_i={a_i}, a_j={a_j}, c={c})",
            pm.wd
        );
    });
}

#[test]
fn prop_merge_pair_consistency() {
    // merge_pair's returned (z, a_z) must achieve the wd it reports
    // when audited with the exact formula.
    forall("merge pair exactness", 200, |rng| {
        let d = 1 + rng.next_below(16);
        let x_i: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        let x_j: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        let a_i = 0.1 + rng.next_f64();
        let a_j = 0.1 + rng.next_f64();
        let gamma = 0.05 + rng.next_f64() * 3.0;
        let (z, a_z, wd) = golden::merge_pair(&x_i, a_i, &x_j, a_j, gamma, GS_ITERS);
        let pts: Vec<(&[f32], f64)> = vec![(&x_i, a_i), (&x_j, a_j)];
        let audit = exact_multi_wd(&pts, &z, a_z, gamma);
        assert!(
            (audit - wd).abs() < 1e-6 * (1.0 + wd.abs()),
            "reported wd {wd} vs audited {audit}"
        );
    });
}

#[test]
fn prop_maintenance_always_enforces_budget_and_nonnegative_wd() {
    forall("maintenance enforces budget", 60, |rng| {
        let d = 1 + rng.next_below(8);
        let b = 4 + rng.next_below(40);
        let overflow = 1 + rng.next_below(6);
        let kinds = [
            MaintenanceKind::Removal,
            MaintenanceKind::Projection,
            MaintenanceKind::Merge { m: 2 + rng.next_below(6) },
            MaintenanceKind::MergeGd { m: 2 + rng.next_below(6) },
        ];
        let kind = kinds[rng.next_below(4)];
        let mut svs = random_store(rng, b + overflow, d, true);
        let mut budget = Budget::new(b, kind);
        let mut be = NativeBackend::new();
        let gamma = 0.1 + rng.next_f64() * 2.0;
        budget.enforce(&mut svs, gamma, &mut be);
        assert!(svs.len() <= b, "{kind:?} left {} > {b}", svs.len());
        assert!(budget.total_wd >= -1e-6, "{kind:?} negative wd {}", budget.total_wd);
        for j in 0..svs.len() {
            assert!(svs.alpha(j).is_finite());
            assert!(svs.point(j).iter().all(|v| v.is_finite()));
        }
    });
}

#[test]
fn prop_margin_linearity_in_alpha() {
    // margins are linear in the coefficient vector: scaling every α by c
    // scales every margin by c.
    forall("margin linearity", 100, |rng| {
        let d = 1 + rng.next_below(12);
        let b = 3 + rng.next_below(30);
        let mut svs = random_store(rng, b, d, true);
        let x: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        let gamma = 0.1 + rng.next_f64();
        let mut be = NativeBackend::new();
        let f1 = be.margin1(&svs, gamma, &x);
        let c = 0.25 + rng.next_f64();
        svs.scale_all(c);
        let f2 = be.margin1(&svs, gamma, &x);
        assert!(
            (f2 - c * f1).abs() < 1e-9 * (1.0 + f1.abs()),
            "margin not linear: {f2} vs {}",
            c * f1
        );
    });
}

#[test]
fn prop_gaussian_kernel_psd_on_small_sets() {
    // 3-point Gram matrices must be PSD (Mercer): check via eigen-free
    // criteria (diagonal 1, symmetric, det of all leading minors >= 0).
    forall("gaussian psd", 200, |rng| {
        let d = 1 + rng.next_below(6);
        let gamma = 0.1 + rng.next_f64() * 4.0;
        let kern = Gaussian::new(gamma);
        let pts: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..d).map(|_| rng.next_gaussian() as f32).collect())
            .collect();
        let k = |i: usize, j: usize| kern.eval(&pts[i], &pts[j]);
        let (a, b, c) = (k(0, 1), k(0, 2), k(1, 2));
        // leading minors of [[1,a,b],[a,1,c],[b,c,1]]
        let m2 = 1.0 - a * a;
        let m3 = 1.0 + 2.0 * a * b * c - a * a - b * b - c * c;
        assert!(m2 >= -1e-12, "2x2 minor {m2}");
        assert!(m3 >= -1e-9, "3x3 minor {m3}");
    });
}

#[test]
fn prop_sq_dist_metric_axioms() {
    forall("sq_dist axioms", 200, |rng| {
        let d = 1 + rng.next_below(64);
        let x: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        let y: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        let z: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32).collect();
        assert!(sq_dist(&x, &x) < 1e-12);
        assert!((sq_dist(&x, &y) - sq_dist(&y, &x)).abs() < 1e-9);
        // triangle inequality on the *root* distances
        let (dxy, dyz, dxz) = (
            sq_dist(&x, &y).sqrt(),
            sq_dist(&y, &z).sqrt(),
            sq_dist(&x, &z).sqrt(),
        );
        assert!(dxz <= dxy + dyz + 1e-6);
    });
}

#[test]
fn prop_training_is_seed_deterministic_and_budget_safe() {
    forall("training determinism", 6, |rng| {
        let scale = 0.005 + rng.next_f64() * 0.01;
        let spec = SynthSpec::ijcnn_like(scale);
        let split = dataset(&spec, rng.next_u64());
        let cfg = TrainConfig {
            lambda: TrainConfig::lambda_from_c(spec.c, split.train.len()),
            gamma: spec.gamma,
            budget: 8 + rng.next_below(40),
            mergees: 2 + rng.next_below(8),
            epochs: 1,
            seed: rng.next_u64(),
            ..TrainConfig::default()
        };
        let a = bsgd::train(&split.train, &cfg).unwrap();
        let b = bsgd::train(&split.train, &cfg).unwrap();
        assert!(a.model.svs.len() <= cfg.budget);
        assert_eq!(a.margin_violations, b.margin_violations);
        assert_eq!(a.model.svs.points_flat(), b.model.svs.points_flat());
        let acc = a.model.accuracy(&split.test);
        assert!((0.0..=1.0).contains(&acc));
    });
}

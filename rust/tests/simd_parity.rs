//! SIMD-substrate acceptance tests (ISSUE 5 tentpole):
//!
//! 1. **Dispatch parity** — the runtime-dispatched `dot` / `sq_dist` /
//!    `dot_block` are bit-identical to the scalar reference path over
//!    ragged shapes (d ∈ {1, 3, 7, 8, 9, 31, 128, 300}), including the
//!    cancellation-dominated large-norm regression from
//!    `kernel/mod.rs` — the fixed 8-lane accumulator layout is the
//!    contract, not an approximation.
//! 2. **Mode invariance end to end** — `train_full` and
//!    `merge_scores_batch` produce identical bits for
//!    `simd_mode ∈ {auto, scalar}` × `threads ∈ {1, 2, 4}`: the ISA,
//!    like the thread count, is a pure wall-clock knob.
//!
//! CI runs this whole binary (plus `tile_engine`) twice — once normally
//! and once under `MMBSGD_FORCE_SCALAR=1` — so both halves of every
//! parity pair are exercised as the *ambient* dispatch too.
//!
//! Tests that flip the process-wide mode serialize on `MODE_LOCK`
//! (flipping is harmless to results — that is the invariant under test
//! — but a parity test sampling "dispatched" mid-flip would silently
//! compare scalar against scalar and prove nothing).

use mmbsgd::config::TrainConfig;
use mmbsgd::data::synth::{dataset, SynthSpec};
use mmbsgd::kernel::{self, simd, SimdMode};
use mmbsgd::model::SvStore;
use mmbsgd::rng::Xoshiro256;
use mmbsgd::runtime::{Backend, NativeBackend};
use mmbsgd::solver::bsgd;
use mmbsgd::solver::NoopObserver;
use std::sync::Mutex;

static MODE_LOCK: Mutex<()> = Mutex::new(());

fn lock_mode() -> std::sync::MutexGuard<'static, ()> {
    MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Test vectors with mixed magnitudes and signs (both exp branches,
/// non-trivial remainders).
fn vecs(d: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Xoshiro256::new(seed);
    let a: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32 * 2.5).collect();
    let b: Vec<f32> = (0..d)
        .map(|_| rng.next_gaussian() as f32 * 0.4 - 0.7)
        .collect();
    (a, b)
}

const DIMS: [usize; 9] = [0, 1, 3, 7, 8, 9, 31, 128, 300];

#[test]
fn dispatched_dot_and_sq_dist_bit_match_scalar() {
    let _g = lock_mode();
    for d in DIMS {
        let (a, b) = vecs(d, d as u64 + 1);
        assert_eq!(
            simd::dot(&a, &b).to_bits(),
            simd::dot_scalar(&a, &b).to_bits(),
            "dot d={d} isa={:?}",
            simd::active_isa()
        );
        assert_eq!(
            simd::sq_dist(&a, &b).to_bits(),
            simd::sq_dist_scalar(&a, &b).to_bits(),
            "sq_dist d={d}"
        );
        // and through the public kernel entry points
        assert_eq!(kernel::dot(&a, &b).to_bits(), simd::dot_scalar(&a, &b).to_bits());
        assert_eq!(
            kernel::sq_dist(&a, &b).to_bits(),
            simd::sq_dist_scalar(&a, &b).to_bits()
        );
    }
}

#[test]
fn dispatched_dot_block_bit_matches_scalar_over_ragged_row_counts() {
    let _g = lock_mode();
    for d in DIMS {
        for rows_n in [1usize, 2, 3, 4, 5, 6, 7, 8, 9, 13, 32] {
            let mut rng = Xoshiro256::new((d * 1000 + rows_n) as u64 + 5);
            let q: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32 * 1.3).collect();
            let rows: Vec<f32> = (0..rows_n * d)
                .map(|_| rng.next_gaussian() as f32 * 0.8)
                .collect();
            let mut got = vec![0.0f64; rows_n];
            simd::dot_block(&q, &rows, d, &mut got);
            let mut want = vec![0.0f64; rows_n];
            simd::dot_block_scalar(&q, &rows, d, &mut want);
            for r in 0..rows_n {
                assert_eq!(
                    got[r].to_bits(),
                    want[r].to_bits(),
                    "dot_block d={d} rows={rows_n} row {r} isa={:?}",
                    simd::active_isa()
                );
            }
        }
    }
}

#[test]
fn sq_dist_cached_parity_survives_cancellation_regression() {
    // The large-norm near-duplicate regression from kernel/mod.rs: the
    // norm expansion is cancellation-dominated, so the guard must route
    // through the exact difference form — and it must make the *same*
    // decision whether the dot came from the dispatched path, the
    // scalar path, or the block micro-kernel.
    let _g = lock_mode();
    for d in [8usize, 128, 300] {
        let mut rng = Xoshiro256::new(d as u64);
        let a: Vec<f32> = (0..d)
            .map(|_| 200.0 + (rng.next_gaussian() as f32) * 0.5)
            .collect();
        let mut b = a.clone();
        for (i, v) in b.iter_mut().enumerate() {
            *v += 5e-3 * ((i as f32) * 1.3).cos();
        }
        let (na, nb) = (kernel::sq_norm(&a), kernel::sq_norm(&b));
        let dispatched = kernel::sq_dist_cached(&a, na, &b, nb);
        let via_scalar_dot =
            kernel::sq_dist_cached_with_dot(&a, na, &b, nb, simd::dot_scalar(&a, &b));
        let mut block_dot = [0.0f64];
        simd::dot_block(&a, &b, d, &mut block_dot);
        let via_block_dot = kernel::sq_dist_cached_with_dot(&a, na, &b, nb, block_dot[0]);
        assert_eq!(dispatched.to_bits(), via_scalar_dot.to_bits(), "d={d}");
        assert_eq!(dispatched.to_bits(), via_block_dot.to_bits(), "d={d}");
        // the guard actually fired into the accurate branch
        let exact = simd::sq_dist_scalar(&a, &b);
        assert!(
            (dispatched - exact).abs() <= 1e-3 * exact,
            "cancellation not handled at d={d}: {dispatched} vs {exact}"
        );
    }
}

#[test]
fn forced_scalar_mode_bit_matches_auto_on_kernels() {
    // Flip the process-wide mode and pin that the *public* entry
    // points do not change a single bit (this is what makes the knob —
    // and MMBSGD_FORCE_SCALAR — safe to flip on a live system).
    let _g = lock_mode();
    let mut auto_vals = Vec::new();
    simd::set_mode(SimdMode::Auto);
    for d in DIMS {
        let (a, b) = vecs(d, d as u64 + 40);
        auto_vals.push((kernel::dot(&a, &b), kernel::sq_dist(&a, &b)));
    }
    simd::set_mode(SimdMode::Scalar);
    assert_eq!(simd::active_isa(), simd::Isa::Scalar);
    for (i, &d) in DIMS.iter().enumerate() {
        let (a, b) = vecs(d, d as u64 + 40);
        assert_eq!(kernel::dot(&a, &b).to_bits(), auto_vals[i].0.to_bits(), "d={d}");
        assert_eq!(kernel::sq_dist(&a, &b).to_bits(), auto_vals[i].1.to_bits(), "d={d}");
    }
    simd::set_mode(SimdMode::Auto);
}

fn random_store(b: usize, d: usize, seed: u64) -> SvStore {
    let mut rng = Xoshiro256::new(seed);
    let mut s = SvStore::new(d);
    let scale = if d > 0 { (5.0 / d as f64).sqrt() as f32 } else { 1.0 };
    for j in 0..b {
        let shift = if j % 3 == 0 { 4.0f32 } else { 0.0 };
        let x: Vec<f32> = (0..d)
            .map(|_| shift + scale * rng.next_gaussian() as f32)
            .collect();
        let mut a = 0.05 + rng.next_f64();
        if rng.next_f64() < 0.5 {
            a = -a;
        }
        s.push(&x, a);
    }
    s
}

#[test]
fn train_full_bit_invariant_across_simd_mode_and_threads() {
    let _g = lock_mode();
    let split = dataset(&SynthSpec::ijcnn_like(0.02), 13);
    let run = |mode: SimdMode, threads: usize| {
        simd::set_mode(mode);
        let cfg = TrainConfig {
            lambda: 1e-3,
            gamma: 2.0,
            budget: 24,
            mergees: 3,
            eval_every: 150,
            threads,
            simd_mode: mode,
            seed: 7,
            ..TrainConfig::default()
        };
        let mut be = NativeBackend::new();
        let out =
            bsgd::train_full(&split.train, &cfg, &mut be, Some(&split.test), &mut NoopObserver)
                .unwrap();
        simd::set_mode(SimdMode::Auto);
        out
    };
    let base = run(SimdMode::Auto, 1);
    assert!(base.maintenance_events > 0, "budget never hit — test is vacuous");
    for mode in [SimdMode::Auto, SimdMode::Scalar] {
        for threads in [1usize, 2, 4] {
            if mode == SimdMode::Auto && threads == 1 {
                continue; // that's `base`
            }
            let out = run(mode, threads);
            assert_eq!(out.steps, base.steps, "{mode:?} t={threads}");
            assert_eq!(out.maintenance_events, base.maintenance_events);
            assert_eq!(out.model.svs.points_flat(), base.model.svs.points_flat());
            let (a, b) = (out.model.svs.alphas_vec(), base.model.svs.alphas_vec());
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "alpha drift {mode:?} t={threads}");
            }
            assert_eq!(out.model.bias.to_bits(), base.model.bias.to_bits());
            assert_eq!(out.history.len(), base.history.len());
            for (p, q) in out.history.iter().zip(&base.history) {
                assert_eq!(p.accuracy.to_bits(), q.accuracy.to_bits());
            }
        }
    }
}

#[test]
fn merge_scores_batch_bit_invariant_across_simd_mode_and_threads() {
    let _g = lock_mode();
    let svs = random_store(400, 24, 21);
    let cands = [0usize, 17, 203, 399];
    let score = |mode: SimdMode, threads: usize| {
        simd::set_mode(mode);
        let mut be = NativeBackend::new();
        be.set_threads(threads);
        let rows = be.merge_scores_batch(&svs, 1.3, &cands);
        simd::set_mode(SimdMode::Auto);
        rows
    };
    let base = score(SimdMode::Auto, 1);
    for mode in [SimdMode::Auto, SimdMode::Scalar] {
        for threads in [1usize, 2, 4] {
            let got = score(mode, threads);
            for (c, (x, y)) in got.iter().zip(&base).enumerate() {
                for lane in 0..svs.len() {
                    assert_eq!(
                        x.wd[lane].to_bits(),
                        y.wd[lane].to_bits(),
                        "{mode:?} t={threads} c{c} lane{lane}"
                    );
                    assert_eq!(x.h[lane].to_bits(), y.h[lane].to_bits());
                    assert_eq!(x.a_z[lane].to_bits(), y.a_z[lane].to_bits());
                    assert_eq!(x.d2[lane].to_bits(), y.d2[lane].to_bits());
                }
            }
        }
    }
}

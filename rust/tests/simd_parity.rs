//! SIMD-substrate acceptance tests (ISSUE 5 tentpole):
//!
//! 1. **Dispatch parity** — the runtime-dispatched `dot` / `sq_dist` /
//!    `dot_block` are bit-identical to the scalar reference path over
//!    ragged shapes (d ∈ {1, 3, 7, 8, 9, 31, 128, 300}), including the
//!    cancellation-dominated large-norm regression from
//!    `kernel/mod.rs` — the fixed 8-lane accumulator layout is the
//!    contract, not an approximation.
//! 2. **Mode invariance end to end** — `train_full` and
//!    `merge_scores_batch` produce identical bits for
//!    `simd_mode ∈ {auto, scalar}` × `threads ∈ {1, 2, 4}`: the ISA,
//!    like the thread count, is a pure wall-clock knob.
//!
//! CI runs this whole binary (plus `tile_engine`) twice — once normally
//! and once under `MMBSGD_FORCE_SCALAR=1` — so both halves of every
//! parity pair are exercised as the *ambient* dispatch too.
//!
//! Tests that flip the process-wide mode serialize on `MODE_LOCK`
//! (flipping is harmless to results — that is the invariant under test
//! — but a parity test sampling "dispatched" mid-flip would silently
//! compare scalar against scalar and prove nothing).

use mmbsgd::config::TrainConfig;
use mmbsgd::data::synth::{dataset, SynthSpec};
use mmbsgd::kernel::{self, simd, ExpMode, SimdMode};
use mmbsgd::model::SvStore;
use mmbsgd::rng::Xoshiro256;
use mmbsgd::runtime::{Backend, NativeBackend};
use mmbsgd::solver::bsgd;
use mmbsgd::solver::NoopObserver;
use std::sync::Mutex;

static MODE_LOCK: Mutex<()> = Mutex::new(());

fn lock_mode() -> std::sync::MutexGuard<'static, ()> {
    MODE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Test vectors with mixed magnitudes and signs (both exp branches,
/// non-trivial remainders).
fn vecs(d: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = Xoshiro256::new(seed);
    let a: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32 * 2.5).collect();
    let b: Vec<f32> = (0..d)
        .map(|_| rng.next_gaussian() as f32 * 0.4 - 0.7)
        .collect();
    (a, b)
}

const DIMS: [usize; 9] = [0, 1, 3, 7, 8, 9, 31, 128, 300];

#[test]
fn dispatched_dot_and_sq_dist_bit_match_scalar() {
    let _g = lock_mode();
    for d in DIMS {
        let (a, b) = vecs(d, d as u64 + 1);
        assert_eq!(
            simd::dot(&a, &b).to_bits(),
            simd::dot_scalar(&a, &b).to_bits(),
            "dot d={d} isa={:?}",
            simd::active_isa()
        );
        assert_eq!(
            simd::sq_dist(&a, &b).to_bits(),
            simd::sq_dist_scalar(&a, &b).to_bits(),
            "sq_dist d={d}"
        );
        // and through the public kernel entry points
        assert_eq!(kernel::dot(&a, &b).to_bits(), simd::dot_scalar(&a, &b).to_bits());
        assert_eq!(
            kernel::sq_dist(&a, &b).to_bits(),
            simd::sq_dist_scalar(&a, &b).to_bits()
        );
    }
}

#[test]
fn dispatched_dot_block_bit_matches_scalar_over_ragged_row_counts() {
    let _g = lock_mode();
    for d in DIMS {
        for rows_n in [1usize, 2, 3, 4, 5, 6, 7, 8, 9, 13, 32] {
            let mut rng = Xoshiro256::new((d * 1000 + rows_n) as u64 + 5);
            let q: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32 * 1.3).collect();
            let rows: Vec<f32> = (0..rows_n * d)
                .map(|_| rng.next_gaussian() as f32 * 0.8)
                .collect();
            let mut got = vec![0.0f64; rows_n];
            simd::dot_block(&q, &rows, d, &mut got);
            let mut want = vec![0.0f64; rows_n];
            simd::dot_block_scalar(&q, &rows, d, &mut want);
            for r in 0..rows_n {
                assert_eq!(
                    got[r].to_bits(),
                    want[r].to_bits(),
                    "dot_block d={d} rows={rows_n} row {r} isa={:?}",
                    simd::active_isa()
                );
            }
        }
    }
}

#[test]
fn sq_dist_cached_parity_survives_cancellation_regression() {
    // The large-norm near-duplicate regression from kernel/mod.rs: the
    // norm expansion is cancellation-dominated, so the guard must route
    // through the exact difference form — and it must make the *same*
    // decision whether the dot came from the dispatched path, the
    // scalar path, or the block micro-kernel.
    let _g = lock_mode();
    for d in [8usize, 128, 300] {
        let mut rng = Xoshiro256::new(d as u64);
        let a: Vec<f32> = (0..d)
            .map(|_| 200.0 + (rng.next_gaussian() as f32) * 0.5)
            .collect();
        let mut b = a.clone();
        for (i, v) in b.iter_mut().enumerate() {
            *v += 5e-3 * ((i as f32) * 1.3).cos();
        }
        let (na, nb) = (kernel::sq_norm(&a), kernel::sq_norm(&b));
        let dispatched = kernel::sq_dist_cached(&a, na, &b, nb);
        let via_scalar_dot =
            kernel::sq_dist_cached_with_dot(&a, na, &b, nb, simd::dot_scalar(&a, &b));
        let mut block_dot = [0.0f64];
        simd::dot_block(&a, &b, d, &mut block_dot);
        let via_block_dot = kernel::sq_dist_cached_with_dot(&a, na, &b, nb, block_dot[0]);
        assert_eq!(dispatched.to_bits(), via_scalar_dot.to_bits(), "d={d}");
        assert_eq!(dispatched.to_bits(), via_block_dot.to_bits(), "d={d}");
        // the guard actually fired into the accurate branch
        let exact = simd::sq_dist_scalar(&a, &b);
        assert!(
            (dispatched - exact).abs() <= 1e-3 * exact,
            "cancellation not handled at d={d}: {dispatched} vs {exact}"
        );
    }
}

#[test]
fn forced_scalar_mode_bit_matches_auto_on_kernels() {
    // Flip the process-wide mode and pin that the *public* entry
    // points do not change a single bit (this is what makes the knob —
    // and MMBSGD_FORCE_SCALAR — safe to flip on a live system).
    let _g = lock_mode();
    let mut auto_vals = Vec::new();
    simd::set_mode(SimdMode::Auto);
    for d in DIMS {
        let (a, b) = vecs(d, d as u64 + 40);
        auto_vals.push((kernel::dot(&a, &b), kernel::sq_dist(&a, &b)));
    }
    simd::set_mode(SimdMode::Scalar);
    assert_eq!(simd::active_isa(), simd::Isa::Scalar);
    for (i, &d) in DIMS.iter().enumerate() {
        let (a, b) = vecs(d, d as u64 + 40);
        assert_eq!(kernel::dot(&a, &b).to_bits(), auto_vals[i].0.to_bits(), "d={d}");
        assert_eq!(kernel::sq_dist(&a, &b).to_bits(), auto_vals[i].1.to_bits(), "d={d}");
    }
    simd::set_mode(SimdMode::Auto);
}

/// True when the environment pins libm (`MMBSGD_FORCE_LIBM`): the
/// vector-mode halves of the exp tests degenerate to libm-vs-libm and
/// stay green, but assertions that *require* the polynomial to be
/// active must be skipped.
fn env_pins_libm() -> bool {
    matches!(std::env::var("MMBSGD_FORCE_LIBM"), Ok(v) if !(v.is_empty() || v == "0"))
}

#[test]
fn exp_poly_rel_err_bounded_over_gamma_d2_range() {
    // The full γd² domain the hot paths can hand the substrate: a dense
    // sweep of [0, EXP_NEG_CUTOFF) — everything past the cutoff is
    // branch-skipped before any exp — plus a fine band straddling the
    // cutoff boundary itself and the clamp region far beyond.
    let check = |x: f64| {
        let got = simd::exp_neg_poly(x);
        let want = (-x).exp();
        let rel = ((got - want) / want).abs();
        assert!(rel <= 1e-6, "x={x}: poly {got:e} vs libm {want:e} (rel {rel:.3e})");
    };
    let n = 100_000;
    for i in 0..n {
        check(kernel::EXP_NEG_CUTOFF * (i as f64) / (n as f64));
    }
    for i in 0..=4000 {
        check(kernel::EXP_NEG_CUTOFF - 1e-3 + 2e-3 * (i as f64) / 4000.0);
    }
    // the clamp region: monotone-safe tiny positives, never 0, inf, NaN
    for x in [100.0, 708.0, 709.0, 1e6, f64::INFINITY] {
        let got = simd::exp_neg_poly(x);
        assert!(got > 0.0 && got < 1e-300, "x={x}: clamp gave {got:e}");
    }
    // negative arguments clamp to x=0 exactly
    assert_eq!(simd::exp_neg_poly(-5.0).to_bits(), simd::exp_neg_poly(0.0).to_bits());
}

#[test]
fn exp_block_dispatch_bit_matches_forced_scalar() {
    // The cross-ISA determinism contract: the dispatched SIMD block
    // evaluator and the forced-scalar reference produce identical bits
    // for every element, over ragged lengths covering every tail case.
    let _g = lock_mode();
    let mut rng = Xoshiro256::new(77);
    for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 31, 128, 301] {
        let args: Vec<f64> = (0..n)
            .map(|_| rng.next_f64() * 1.2 * kernel::EXP_NEG_CUTOFF)
            .collect();
        simd::set_mode(SimdMode::Auto);
        let mut auto_out = vec![0.0f64; n];
        simd::exp_neg_block(&args, &mut auto_out);
        simd::set_mode(SimdMode::Scalar);
        let mut scalar_out = vec![0.0f64; n];
        simd::exp_neg_block(&args, &mut scalar_out);
        simd::set_mode(SimdMode::Auto);
        for j in 0..n {
            assert_eq!(
                auto_out[j].to_bits(),
                scalar_out[j].to_bits(),
                "n={n} j={j} x={} isa={:?}",
                args[j],
                simd::active_isa()
            );
            // and each lane equals the scalar polynomial reference
            assert_eq!(auto_out[j].to_bits(), simd::exp_neg_poly(args[j]).to_bits());
        }
    }
}

#[test]
fn exp_neg_routes_by_mode() {
    let _g = lock_mode();
    let x = 3.25f64;
    simd::set_exp_mode(ExpMode::Vector);
    let vector = simd::exp_neg(x);
    assert_eq!(
        simd::exp_mode(),
        if env_pins_libm() { ExpMode::Libm } else { ExpMode::Vector }
    );
    simd::set_exp_mode(ExpMode::Libm);
    let libm = simd::exp_neg(x);
    assert_eq!(simd::exp_mode(), ExpMode::Libm);
    assert_eq!(libm.to_bits(), (-x).exp().to_bits());
    if !env_pins_libm() {
        assert_eq!(vector.to_bits(), simd::exp_neg_poly(x).to_bits());
    }
}

fn random_store(b: usize, d: usize, seed: u64) -> SvStore {
    let mut rng = Xoshiro256::new(seed);
    let mut s = SvStore::new(d);
    let scale = if d > 0 { (5.0 / d as f64).sqrt() as f32 } else { 1.0 };
    for j in 0..b {
        let shift = if j % 3 == 0 { 4.0f32 } else { 0.0 };
        let x: Vec<f32> = (0..d)
            .map(|_| shift + scale * rng.next_gaussian() as f32)
            .collect();
        let mut a = 0.05 + rng.next_f64();
        if rng.next_f64() < 0.5 {
            a = -a;
        }
        s.push(&x, a);
    }
    s
}

#[test]
fn train_full_bit_invariant_across_simd_mode_and_threads() {
    let _g = lock_mode();
    let split = dataset(&SynthSpec::ijcnn_like(0.02), 13);
    let run = |mode: SimdMode, threads: usize| {
        simd::set_mode(mode);
        let cfg = TrainConfig {
            lambda: 1e-3,
            gamma: 2.0,
            budget: 24,
            mergees: 3,
            eval_every: 150,
            threads,
            simd_mode: mode,
            seed: 7,
            ..TrainConfig::default()
        };
        let mut be = NativeBackend::new();
        let out =
            bsgd::train_full(&split.train, &cfg, &mut be, Some(&split.test), &mut NoopObserver)
                .unwrap();
        simd::set_mode(SimdMode::Auto);
        out
    };
    let base = run(SimdMode::Auto, 1);
    assert!(base.maintenance_events > 0, "budget never hit — test is vacuous");
    for mode in [SimdMode::Auto, SimdMode::Scalar] {
        for threads in [1usize, 2, 4] {
            if mode == SimdMode::Auto && threads == 1 {
                continue; // that's `base`
            }
            let out = run(mode, threads);
            assert_eq!(out.steps, base.steps, "{mode:?} t={threads}");
            assert_eq!(out.maintenance_events, base.maintenance_events);
            assert_eq!(out.model.svs.points_flat(), base.model.svs.points_flat());
            let (a, b) = (out.model.svs.alphas_vec(), base.model.svs.alphas_vec());
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "alpha drift {mode:?} t={threads}");
            }
            assert_eq!(out.model.bias.to_bits(), base.model.bias.to_bits());
            assert_eq!(out.history.len(), base.history.len());
            for (p, q) in out.history.iter().zip(&base.history) {
                assert_eq!(p.accuracy.to_bits(), q.accuracy.to_bits());
            }
        }
    }
}

#[test]
fn merge_scores_batch_bit_invariant_across_simd_mode_and_threads() {
    let _g = lock_mode();
    let svs = random_store(400, 24, 21);
    let cands = [0usize, 17, 203, 399];
    let score = |mode: SimdMode, threads: usize| {
        simd::set_mode(mode);
        let mut be = NativeBackend::new();
        be.set_threads(threads);
        let rows = be.merge_scores_batch(&svs, 1.3, &cands);
        simd::set_mode(SimdMode::Auto);
        rows
    };
    let base = score(SimdMode::Auto, 1);
    for mode in [SimdMode::Auto, SimdMode::Scalar] {
        for threads in [1usize, 2, 4] {
            let got = score(mode, threads);
            for (c, (x, y)) in got.iter().zip(&base).enumerate() {
                for lane in 0..svs.len() {
                    assert_eq!(
                        x.wd[lane].to_bits(),
                        y.wd[lane].to_bits(),
                        "{mode:?} t={threads} c{c} lane{lane}"
                    );
                    assert_eq!(x.h[lane].to_bits(), y.h[lane].to_bits());
                    assert_eq!(x.a_z[lane].to_bits(), y.a_z[lane].to_bits());
                    assert_eq!(x.d2[lane].to_bits(), y.d2[lane].to_bits());
                }
            }
        }
    }
}

#[test]
fn merge_scores_batch_invariant_across_exp_mode() {
    // Vector mode keeps the determinism contract (bit-identical across
    // ISA × threads) and stays within the substrate's accuracy envelope
    // of the libm results.  d² never touches an exponent, so it must
    // not move a single bit between modes.
    let _g = lock_mode();
    let svs = random_store(400, 24, 21);
    let cands = [0usize, 17, 203, 399];
    let score = |exp: ExpMode, mode: SimdMode, threads: usize| {
        simd::set_mode(mode);
        simd::set_exp_mode(exp);
        let mut be = NativeBackend::new();
        be.set_threads(threads);
        let rows = be.merge_scores_batch(&svs, 1.3, &cands);
        simd::set_mode(SimdMode::Auto);
        simd::set_exp_mode(ExpMode::Libm);
        rows
    };
    let libm = score(ExpMode::Libm, SimdMode::Auto, 1);
    let base = score(ExpMode::Vector, SimdMode::Auto, 1);
    for mode in [SimdMode::Auto, SimdMode::Scalar] {
        for threads in [1usize, 2, 4] {
            let got = score(ExpMode::Vector, mode, threads);
            for (c, (x, y)) in got.iter().zip(&base).enumerate() {
                for lane in 0..svs.len() {
                    assert_eq!(
                        x.wd[lane].to_bits(),
                        y.wd[lane].to_bits(),
                        "vector {mode:?} t={threads} c{c} lane{lane}"
                    );
                    assert_eq!(x.h[lane].to_bits(), y.h[lane].to_bits());
                    assert_eq!(x.a_z[lane].to_bits(), y.a_z[lane].to_bits());
                    assert_eq!(x.d2[lane].to_bits(), y.d2[lane].to_bits());
                }
            }
        }
    }
    for (c, (x, y)) in base.iter().zip(&libm).enumerate() {
        for lane in 0..svs.len() {
            assert_eq!(x.d2[lane].to_bits(), y.d2[lane].to_bits(), "d2 moved c{c} lane{lane}");
            let tol = |v: f64| 1e-5 * (1.0 + v.abs());
            assert!((x.wd[lane] - y.wd[lane]).abs() <= tol(y.wd[lane]), "wd c{c} lane{lane}");
            assert!((x.h[lane] - y.h[lane]).abs() <= 1e-4, "h c{c} lane{lane}");
            assert!((x.a_z[lane] - y.a_z[lane]).abs() <= tol(y.a_z[lane]), "a_z c{c} lane{lane}");
        }
    }
}

#[test]
fn train_full_invariant_across_exp_mode_simd_mode_and_threads() {
    // exp_mode = vector must be exactly as deterministic as libm mode:
    // every (simd_mode, threads) combination reproduces the same bits.
    // Across the two exp modes, training follows the same schedule and
    // lands at equivalent accuracy (the 1e-6 exp envelope may reorder
    // near-tie merge choices, so cross-mode equality is behavioral, not
    // bitwise — that asymmetry is the documented contract).
    let _g = lock_mode();
    let split = dataset(&SynthSpec::ijcnn_like(0.02), 13);
    let run = |exp: ExpMode, mode: SimdMode, threads: usize| {
        simd::set_mode(mode);
        simd::set_exp_mode(exp);
        let cfg = TrainConfig {
            lambda: 1e-3,
            gamma: 2.0,
            budget: 24,
            mergees: 3,
            eval_every: 150,
            threads,
            simd_mode: mode,
            exp_mode: exp,
            seed: 7,
            ..TrainConfig::default()
        };
        let mut be = NativeBackend::new();
        let out =
            bsgd::train_full(&split.train, &cfg, &mut be, Some(&split.test), &mut NoopObserver)
                .unwrap();
        simd::set_mode(SimdMode::Auto);
        simd::set_exp_mode(ExpMode::Libm);
        out
    };
    let base = run(ExpMode::Vector, SimdMode::Auto, 1);
    assert!(base.maintenance_events > 0, "budget never hit — test is vacuous");
    for mode in [SimdMode::Auto, SimdMode::Scalar] {
        for threads in [1usize, 2, 4] {
            if mode == SimdMode::Auto && threads == 1 {
                continue; // that's `base`
            }
            let out = run(ExpMode::Vector, mode, threads);
            assert_eq!(out.steps, base.steps, "vector {mode:?} t={threads}");
            assert_eq!(out.maintenance_events, base.maintenance_events);
            assert_eq!(out.model.svs.points_flat(), base.model.svs.points_flat());
            let (a, b) = (out.model.svs.alphas_vec(), base.model.svs.alphas_vec());
            assert_eq!(a.len(), b.len());
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.to_bits(), y.to_bits(), "alpha drift vector {mode:?} t={threads}");
            }
            assert_eq!(out.model.bias.to_bits(), base.model.bias.to_bits());
            for (p, q) in out.history.iter().zip(&base.history) {
                assert_eq!(p.accuracy.to_bits(), q.accuracy.to_bits());
            }
        }
    }
    // cross-mode behavioral equivalence: same schedule, same budget
    // pressure, accuracy within noise of each other
    let libm = run(ExpMode::Libm, SimdMode::Auto, 1);
    assert_eq!(base.steps, libm.steps);
    assert!(base.maintenance_events > 0 && libm.maintenance_events > 0);
    let (va, la) = (
        base.history.last().expect("eval ran").accuracy,
        libm.history.last().expect("eval ran").accuracy,
    );
    assert!(
        (va - la).abs() <= 0.05,
        "exp modes diverged: vector acc {va:.4} vs libm acc {la:.4}"
    );
}

//! The injected-fault matrix (ISSUE 6): with the `fault-inject`
//! feature on, every [`mmbsgd::util::fault::site`] is driven through a
//! real fault and the recovery contract is asserted, not assumed:
//!
//! * `durable.write` io  → typed error, last good generation intact;
//! * `durable.write` tear → detected by the checksum footer, resume
//!   falls back to `.prev` and finishes **bit-identical** to an
//!   uninterrupted run;
//! * `libsvm.read` io/truncate → typed error naming the position;
//! * `pool.job` panic → contained by the pool, re-raised to the
//!   caller, pool fully usable afterwards;
//! * `proto.read` stall/io → the server answers late or drops that one
//!   connection, and keeps serving others;
//! * `http.read` stall/io → same contract on the HTTP front end, with
//!   the drop counted by `serve_http_read_errors_total`.
//!
//! Fault state is process-global, so every test holds [`PLAN_LOCK`]
//! for its whole body (not just the armed section — an unguarded
//! `write_atomic` in test A must not race test B's armed plan), and
//! installs its plan via a drop-guard so a panicking test cannot leave
//! its plan armed for the next one.

#![cfg(feature = "fault-inject")]

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

use mmbsgd::config::TrainConfig;
use mmbsgd::data::synth::{dataset, SynthSpec};
use mmbsgd::data::{libsvm, Split};
use mmbsgd::error::FleetError;
use mmbsgd::fleet::{run_router, Artifact, Controller, Provenance, ReplicaState, RouterOptions};
use mmbsgd::model::SvmModel;
use mmbsgd::runtime::{ArtifactRegistry, NativeBackend, WorkerPool};
use mmbsgd::serve::{serve, serve_bound, serve_fleet, ModelRegistry, ServeOptions};
use mmbsgd::solver::bsgd::TrainOutput;
use mmbsgd::solver::{load_checkpoint, Checkpoint, NoopObserver, TrainSession};
use mmbsgd::util::durable::{self, DurableError};
use mmbsgd::util::fault;

static PLAN_LOCK: Mutex<()> = Mutex::new(());

/// Serialize the whole test on [`PLAN_LOCK`] (survives a poisoned
/// mutex: a failed fault test must not wedge the rest of the matrix).
fn serialize() -> MutexGuard<'static, ()> {
    PLAN_LOCK.lock().unwrap_or_else(|p| p.into_inner())
}

/// Clears the installed plan when dropped, even on panic.
struct PlanGuard;

impl Drop for PlanGuard {
    fn drop(&mut self) {
        fault::clear();
    }
}

/// Arm `plan` until the returned guard drops. Caller must already
/// hold the [`serialize`] lock.
fn arm(plan: &str) -> PlanGuard {
    fault::install(fault::FaultPlan::parse(plan).expect("test plan parses"));
    PlanGuard
}

fn scratch(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("mmbsgd_faultmx_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

// ------------------------------------------------------ durable.write

#[test]
fn injected_write_failure_is_typed_and_keeps_last_good() {
    let _serial = serialize();
    let dir = scratch("write_io");
    let p = dir.join("ck.txt");
    durable::write_atomic(&p, "generation one\n").unwrap();
    {
        let _g = arm("durable.write@1=io");
        match durable::write_atomic(&p, "generation two\n") {
            Err(DurableError::Io { detail, .. }) => {
                assert!(detail.contains("injected"), "{detail}")
            }
            other => panic!("expected injected Io error, got {other:?}"),
        }
        assert_eq!(fault::fired(), 1);
    }
    // nothing on disk moved: the failed write never touched the file
    assert_eq!(durable::read_verified(&p).unwrap(), "generation one\n");
    assert!(!durable::prev_path(&p).exists());
    // with the plan gone the same write succeeds and rotates .prev
    durable::write_atomic(&p, "generation two\n").unwrap();
    assert_eq!(durable::read_verified(&p).unwrap(), "generation two\n");
    assert_eq!(durable::read_verified(&durable::prev_path(&p)).unwrap(), "generation one\n");
    let _ = std::fs::remove_dir_all(&dir);
}

fn tiny_cfg(epochs: usize) -> TrainConfig {
    TrainConfig {
        lambda: 1e-3,
        gamma: 2.0,
        budget: 24,
        mergees: 3,
        seed: 41,
        epochs,
        ..TrainConfig::default()
    }
}

fn reference_run(split: &Split, cfg: &TrainConfig) -> TrainOutput {
    let mut be = NativeBackend::new();
    let mut sess = TrainSession::new(cfg.clone(), &mut be).unwrap();
    for _ in 0..cfg.epochs {
        sess.run_epoch(&split.train, None, &mut NoopObserver, 0).unwrap();
    }
    sess.finish()
}

fn run_to(split: &Split, cfg: &TrainConfig, t: u64) -> (String, TrainSession<'static>) {
    // leak one backend per call: test-only, keeps lifetimes trivial
    let be = Box::leak(Box::new(NativeBackend::new()));
    let mut sess = TrainSession::new(cfg.clone(), be).unwrap();
    while sess.steps() < t {
        let left = t - sess.steps();
        sess.run_epoch(&split.train, None, &mut NoopObserver, left).unwrap();
    }
    (sess.checkpoint(), sess)
}

/// A checkpoint write torn mid-stream by the fault plan is detected by
/// the footer, `load_checkpoint` falls back to the `.prev` generation,
/// and the resumed run finishes bit-identical to an uninterrupted one.
#[test]
fn torn_checkpoint_write_recovers_from_prev_bit_identically() {
    let _serial = serialize();
    let split = dataset(&SynthSpec::ijcnn_like(0.01), 6);
    let cfg = tiny_cfg(1);
    let n = split.train.len() as u64;
    let dir = scratch("torn");
    let p = dir.join("ck.txt");

    let (blob_good, _) = run_to(&split, &cfg, n / 3);
    durable::write_atomic(&p, &blob_good).unwrap();
    let (blob_torn, mut sess) = run_to(&split, &cfg, 2 * n / 3);
    {
        let _g = arm(&format!("durable.write@1=truncate:{}", blob_torn.len() / 2));
        // the tear happens *inside* the write pipeline: the rename
        // completes, exactly like power loss between write and fsync
        durable::write_atomic(&p, &blob_torn).unwrap();
        assert_eq!(fault::fired(), 1);
    }

    let loaded = load_checkpoint(&p).expect("must fall back to .prev");
    assert_eq!(loaded.generation, durable::Generation::Prev);
    assert_eq!(loaded.checkpoint.step(), n / 3);
    let why = loaded.primary_error.expect("fallback records why the primary failed");
    assert!(why.contains("at byte"), "{why}");

    // resume from the fallback and run to completion: bit-identical
    // to the uninterrupted reference
    let mut be = NativeBackend::new();
    let mut resumed = loaded.checkpoint.into_session(&mut be).unwrap();
    resumed.run_epoch(&split.train, None, &mut NoopObserver, 0).unwrap();
    let out = resumed.finish();
    let want = reference_run(&split, &cfg);
    assert_eq!(out.model.to_text(), want.model.to_text());
    assert_eq!(out.model.bias.to_bits(), want.model.bias.to_bits());

    // the interrupted session object itself is also still consistent
    // (its own in-memory state never depended on the torn file)
    while sess.steps() < n {
        let left = n - sess.steps();
        sess.run_epoch(&split.train, None, &mut NoopObserver, left).unwrap();
    }
    assert_eq!(sess.finish().model.to_text(), want.model.to_text());
    let _ = std::fs::remove_dir_all(&dir);
}

/// The `MMBSGD_FAULT_PLAN` environment path (what CI's end-to-end job
/// uses) arms exactly like an installed plan.
#[test]
fn env_var_plan_arms_injection() {
    let _serial = serialize();
    fault::clear(); // force the next armed() call to re-read the env
    std::env::set_var("MMBSGD_FAULT_PLAN", "durable.write@1=io");
    let dir = scratch("envplan");
    let p = dir.join("x.txt");
    let got = durable::write_atomic(&p, "payload\n");
    std::env::remove_var("MMBSGD_FAULT_PLAN");
    fault::clear();
    assert!(matches!(got, Err(DurableError::Io { .. })), "env plan did not fire: {got:?}");
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------- libsvm.read

#[test]
fn libsvm_read_faults_are_typed_never_partial() {
    let _serial = serialize();
    let dir = scratch("libsvm");
    let p = dir.join("data.libsvm");
    std::fs::write(&p, "+1 1:0.5\n-1 2:1.5\n").unwrap();
    {
        let _g = arm("libsvm.read@1=io");
        let err = libsvm::load(&p, None).unwrap_err().to_string();
        assert!(err.contains("injected read fault"), "{err}");
    }
    {
        // tear mid-token of line 2: "+1 1:0.5\n-1 2:" — the parser
        // must reject the torn tail with a positioned error, not
        // silently train on half a file
        let _g = arm("libsvm.read@1=truncate:14");
        let err = format!("{:#}", libsvm::load(&p, None).unwrap_err());
        assert!(err.contains("line 2"), "{err}");
    }
    // plan cleared: the same file loads whole
    let ds = libsvm::load(&p, None).unwrap();
    assert_eq!(ds.len(), 2);
    let _ = std::fs::remove_dir_all(&dir);
}

// ---------------------------------------------------------- pool.job

#[test]
fn worker_pool_contains_injected_panic_and_survives() {
    let _serial = serialize();
    let pool = WorkerPool::new(2);
    let hits = AtomicUsize::new(0);
    {
        let _g = arm("pool.job@1=panic");
        let blown = catch_unwind(AssertUnwindSafe(|| {
            pool.run_jobs(vec![0usize, 1, 2, 3], |_| {
                hits.fetch_add(1, Ordering::Relaxed);
            });
        }));
        // the injected panic is caught by the pool's catch_unwind and
        // re-raised scope-style in the caller — never in a detached
        // worker (which would abort the process)
        assert!(blown.is_err(), "injected job panic must propagate to the caller");
    }
    // the pool is not poisoned: the same handle runs the next batch
    hits.store(0, Ordering::Relaxed);
    pool.run_jobs(vec![0usize, 1, 2, 3], |_| {
        hits.fetch_add(1, Ordering::Relaxed);
    });
    assert_eq!(hits.load(Ordering::Relaxed), 4);
}

// --------------------------------------------------------- proto.read

fn trained_model() -> (SvmModel, Vec<f32>) {
    let split = dataset(&SynthSpec::ijcnn_like(0.01), 2);
    let out = mmbsgd::solver::bsgd::train(&split.train, &tiny_cfg(1)).unwrap();
    let q = split.test.x.row(0).to_vec();
    (out.model, q)
}

fn serve_with<R: Send>(model: SvmModel, client: impl FnOnce(SocketAddr) -> R + Send) -> R {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let mut reg = ModelRegistry::new(Box::new(NativeBackend::new()), 1);
    reg.insert("m", model).unwrap();
    let opts = ServeOptions::default();
    let mut seen = None;
    std::thread::scope(|s| {
        let h = s.spawn(move || client(addr));
        serve(listener, reg, &opts).unwrap();
        seen = Some(h.join().unwrap());
    });
    seen.unwrap()
}

fn fmt_row(x: &[f32]) -> String {
    x.iter().map(|v| v.to_string()).collect::<Vec<_>>().join(" ")
}

/// A stalled read delays the connection's loop, but the request still
/// answers and the shutdown handshake completes — a wedged peer path
/// degrades latency, never correctness.
#[test]
fn proto_read_stall_still_answers() {
    let _serial = serialize();
    let (model, q) = trained_model();
    let _g = arm("proto.read@1=stall:120");
    let (first, bye) = serve_with(model, move |addr| {
        let c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut w = c.try_clone().unwrap();
        w.write_all(format!("predict {}\nshutdown\n", fmt_row(&q)).as_bytes()).unwrap();
        w.flush().unwrap();
        let mut r = BufReader::new(c);
        let mut first = String::new();
        r.read_line(&mut first).unwrap();
        let mut bye = String::new();
        r.read_line(&mut bye).unwrap();
        (first.trim().to_string(), bye.trim().to_string())
    });
    assert!(first.starts_with("ok "), "stalled predict still answers: {first}");
    assert_eq!(bye, "ok bye");
}

/// An injected read error drops exactly that connection; the listener
/// keeps accepting, and a fresh connection serves stats and performs
/// the clean shutdown.
#[test]
fn proto_read_error_drops_one_connection_not_the_server() {
    let _serial = serialize();
    let (model, q) = trained_model();
    let _g = arm("proto.read@1=io");
    let (dropped, stats, bye) = serve_with(model, move |addr| {
        // connection A: its very first read visit errors — the server
        // closes it without ever reading the request
        let a = TcpStream::connect(addr).unwrap();
        a.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut wa = a.try_clone().unwrap();
        wa.write_all(format!("predict {}\n", fmt_row(&q)).as_bytes()).unwrap();
        wa.flush().unwrap();
        let mut ra = BufReader::new(a);
        let mut got = String::new();
        // the server never read our request, so its close may surface
        // as clean EOF or as ECONNRESET — both mean "dropped"
        let dropped = matches!(ra.read_line(&mut got), Ok(0) | Err(_));
        // connection B: still served, performs the clean shutdown
        let b = TcpStream::connect(addr).unwrap();
        b.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut wb = b.try_clone().unwrap();
        wb.write_all(b"stats\nshutdown\n").unwrap();
        wb.flush().unwrap();
        let mut rb = BufReader::new(b);
        let mut stats = String::new();
        rb.read_line(&mut stats).unwrap();
        let mut bye = String::new();
        rb.read_line(&mut bye).unwrap();
        (dropped, stats.trim().to_string(), bye.trim().to_string())
    });
    assert!(dropped, "injected read error must close connection A (EOF to the client)");
    assert!(stats.starts_with("ok served="), "{stats}");
    assert_eq!(bye, "ok bye");
}

// --------------------------------------------------------- http.read

/// Like [`serve_with`], but with the HTTP front end bound too; the
/// client closure receives `(line_addr, http_addr)` and must trigger
/// shutdown (via the line port).
fn serve_http_with<R: Send>(
    model: SvmModel,
    client: impl FnOnce(SocketAddr, SocketAddr) -> R + Send,
) -> R {
    let line_l = TcpListener::bind("127.0.0.1:0").unwrap();
    let http_l = TcpListener::bind("127.0.0.1:0").unwrap();
    let (la, ha) = (line_l.local_addr().unwrap(), http_l.local_addr().unwrap());
    let mut reg = ModelRegistry::new(Box::new(NativeBackend::new()), 1);
    reg.insert("m", model).unwrap();
    let opts = ServeOptions::default();
    let mut seen = None;
    std::thread::scope(|s| {
        let h = s.spawn(move || client(la, ha));
        serve_bound(line_l, Some(http_l), reg, &opts).unwrap();
        seen = Some(h.join().unwrap());
    });
    seen.unwrap()
}

/// Read one HTTP response (status line, headers, Content-Length body)
/// and return `(status, body)`.
fn read_http_response(r: &mut BufReader<TcpStream>) -> (u16, String) {
    let mut line = String::new();
    assert!(r.read_line(&mut line).unwrap() > 0, "server closed mid-response");
    let status: u16 = line.split_ascii_whitespace().nth(1).unwrap().parse().unwrap();
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        assert!(r.read_line(&mut h).unwrap() > 0, "server closed mid-headers");
        let t = h.trim();
        if t.is_empty() {
            break;
        }
        let lower = t.to_ascii_lowercase();
        if let Some(v) = lower.strip_prefix("content-length:") {
            content_length = v.trim().parse().unwrap();
        }
    }
    let mut body = vec![0u8; content_length];
    std::io::Read::read_exact(r, &mut body).unwrap();
    (status, String::from_utf8(body).unwrap())
}

fn line_shutdown(addr: SocketAddr) {
    let c = TcpStream::connect(addr).unwrap();
    c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    let mut w = c.try_clone().unwrap();
    w.write_all(b"shutdown\n").unwrap();
    w.flush().unwrap();
    let mut bye = String::new();
    BufReader::new(c).read_line(&mut bye).unwrap();
    assert_eq!(bye.trim(), "ok bye");
}

/// A stalled HTTP read delays that connection's loop; the request
/// still answers 200 and the server shuts down cleanly afterwards.
#[test]
fn http_read_stall_still_answers() {
    let _serial = serialize();
    let (model, _q) = trained_model();
    let _g = arm("http.read@1=stall:120");
    let (status, body) = serve_http_with(model, move |la, ha| {
        let c = TcpStream::connect(ha).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut w = c.try_clone().unwrap();
        w.write_all(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        w.flush().unwrap();
        let got = read_http_response(&mut BufReader::new(c));
        line_shutdown(la);
        got
    });
    assert_eq!(status, 200, "stalled HTTP read still answers");
    assert_eq!(body, "ok\n");
}

/// An injected HTTP read error drops exactly that connection — no
/// response bytes, just a close — increments
/// `serve_http_read_errors_total`, and the front end keeps serving: a
/// fresh connection scrapes `/metrics` and sees the counter at 1.
#[test]
fn http_read_error_drops_one_connection_not_the_front_end() {
    let _serial = serialize();
    let (model, _q) = trained_model();
    let _g = arm("http.read@1=io");
    let (dropped, status, scrape) = serve_http_with(model, move |la, ha| {
        // connection A: its first read visit errors — the server never
        // parses the request and closes without answering
        let a = TcpStream::connect(ha).unwrap();
        a.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut wa = a.try_clone().unwrap();
        wa.write_all(b"GET /healthz HTTP/1.1\r\n\r\n").unwrap();
        wa.flush().unwrap();
        let mut ra = BufReader::new(a);
        let mut got = String::new();
        let dropped = matches!(ra.read_line(&mut got), Ok(0) | Err(_)) && got.is_empty();
        // connection B: still served; the scrape carries A's drop
        let b = TcpStream::connect(ha).unwrap();
        b.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        let mut wb = b.try_clone().unwrap();
        wb.write_all(b"GET /metrics HTTP/1.1\r\nConnection: close\r\n\r\n").unwrap();
        wb.flush().unwrap();
        let (status, scrape) = read_http_response(&mut BufReader::new(b));
        line_shutdown(la);
        (dropped, status, scrape)
    });
    assert!(dropped, "injected read error must close connection A without a response");
    assert_eq!(status, 200);
    assert!(
        scrape.contains("serve_http_read_errors_total 1"),
        "the drop is visible on the metrics surface: {scrape}"
    );
}

// ----------------------------------------------- checkpoint corpus tie-in

/// The fault layer and the corpus agree: a file torn by the injector
/// is rejected by the same verify path the fuzz corpus pins.
#[test]
fn injected_tear_and_manual_tear_fail_identically() {
    let _serial = serialize();
    let dir = scratch("tear_eq");
    let split = dataset(&SynthSpec::ijcnn_like(0.01), 6);
    let (blob, _) = run_to(&split, &tiny_cfg(1), 10);
    let cut = blob.len() / 2;

    let injected = dir.join("injected.txt");
    {
        let _g = arm(&format!("durable.write@1=truncate:{cut}"));
        durable::write_atomic(&injected, &blob).unwrap();
    }
    let manual = dir.join("manual.txt");
    durable::write_atomic(&manual, &blob).unwrap();
    let full = std::fs::read_to_string(&manual).unwrap();
    std::fs::write(&manual, &full[..cut]).unwrap();

    let a = durable::read_verified(&injected).map(|s| Checkpoint::parse(&s).is_ok());
    let b = durable::read_verified(&manual).map(|s| Checkpoint::parse(&s).is_ok());
    match (a, b) {
        (Err(_), Err(_)) | (Ok(false), Ok(false)) => {} // both detected, same layer
        (ga, gb) => panic!("tear detection diverged: injected={ga:?} manual={gb:?}"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------ artifact.read

/// `artifact.read` faults are typed for both consumers of the shared
/// read path: a fleet bundle load ([`Artifact::load`]) and the AOT
/// registry manifest scan ([`ArtifactRegistry::load`]).  `io` fails
/// the read outright; `truncate:K` tears the text before verification
/// so the durable footer rejects it as corrupt.
#[test]
fn artifact_read_faults_are_typed_for_both_consumers() {
    let _serial = serialize();
    let dir = scratch("artifact_read");
    let (model, _) = trained_model();
    let bundle = Artifact::wrap("champ", 1, &model, Provenance::default(), "lut", "auto").unwrap();
    let p = dir.join("champ.artifact");
    bundle.save(&p).unwrap();
    {
        let _g = arm("artifact.read@1=io");
        match Artifact::load(&p) {
            Err(FleetError::Io { detail, .. }) => assert!(detail.contains("injected"), "{detail}"),
            other => panic!("expected injected Io error, got {other:?}"),
        }
        assert_eq!(fault::fired(), 1);
    }
    {
        // tear inside the manifest body: the durable footer is gone
        // entirely (legacy-accept), so the manifest parser is the
        // layer that refuses the torn text
        let _g = arm("artifact.read@1=truncate:40");
        match Artifact::load(&p) {
            Err(FleetError::Manifest { .. }) => {}
            other => panic!("torn manifest must be refused, got {other:?}"),
        }
    }
    {
        // tear inside the footer line itself: the durable layer
        // rejects it as corrupt before any parsing
        let n = std::fs::metadata(&p).unwrap().len();
        let _g = arm(&format!("artifact.read@1=truncate:{}", n - 5));
        match Artifact::load(&p) {
            Err(FleetError::Corrupt { .. }) => {}
            other => panic!("torn footer must fail the checksum gate, got {other:?}"),
        }
    }
    // plan cleared: the same bundle loads whole
    assert_eq!(Artifact::load(&p).unwrap().version, 1);

    // the AOT manifest scan shares the site (manifests without a
    // footer load unchecked, so only the io rule applies there)
    std::fs::write(dir.join("manifest.json"), "{\"artifacts\": []}\n").unwrap();
    {
        let _g = arm("artifact.read@1=io");
        let err = format!("{:#}", ArtifactRegistry::load(&dir).unwrap_err());
        assert!(err.contains("injected artifact read fault"), "{err}");
    }
    assert!(ArtifactRegistry::load(&dir).unwrap().artifacts.is_empty());
    let _ = std::fs::remove_dir_all(&dir);
}

// -------------------------------------------------------- fleet.push

/// A push torn mid-payload by the `fleet.push` fault leaves the
/// replica exactly where it was: the length-delimited reader sees EOF
/// before the payload completes, stages nothing, and the activated
/// version keeps serving.  The same push succeeds once the plan is
/// cleared — convergence by re-running, the control plane's contract.
#[test]
fn torn_artifact_push_leaves_replica_on_last_good() {
    let _serial = serialize();
    let dir = scratch("torn_push");
    let (model, q) = trained_model();
    let v1 = Artifact::wrap("champ", 1, &model, Provenance::default(), "lut", "auto").unwrap();
    let mut m2 = SvmModel::from_text(&model.to_text()).unwrap();
    m2.bias += 1.0;
    let v2 = Artifact::wrap("champ", 2, &m2, Provenance::default(), "lut", "auto").unwrap();

    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::scope(|s| {
        s.spawn(|| {
            let mut rep = ReplicaState::new(&dir).unwrap();
            let reg = ModelRegistry::new(Box::new(NativeBackend::new()), 7);
            serve_fleet(listener, reg, &ServeOptions::default(), &mut rep).unwrap();
        });
        let mut ctl = Controller::new(vec![addr.to_string()], Duration::from_secs(10));
        assert_eq!(ctl.push(&v1, true)[0].result, Ok(1));

        let ask = |line: &str| {
            let c = TcpStream::connect(addr).unwrap();
            c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
            let mut w = c.try_clone().unwrap();
            w.write_all(format!("{line}\n").as_bytes()).unwrap();
            w.flush().unwrap();
            let mut r = BufReader::new(c);
            let mut reply = String::new();
            r.read_line(&mut reply).unwrap();
            reply.trim_end().to_string()
        };
        let v1_reply = ask(&format!("decision {}", fmt_row(&q)));
        assert!(v1_reply.starts_with("ok "), "{v1_reply}");

        {
            let _g = arm("fleet.push@1=io");
            let out = ctl.push(&v2, true);
            match &out[0].result {
                Err(FleetError::Replica { detail, .. }) => {
                    assert!(detail.contains("torn mid-payload"), "{detail}")
                }
                other => panic!("torn push must be a typed Replica error, got {other:?}"),
            }
            assert_eq!(fault::fired(), 1);
            assert_eq!(ctl.acked(&addr.to_string(), "champ"), Some(1), "ack stays at v1");
        }

        // nothing staged, v1 still serving, answers unchanged
        let status = ask("fleet-status");
        assert!(status.contains("champ@v1"), "{status}");
        assert!(status.contains("staged=0"), "{status}");
        assert_eq!(ask(&format!("decision {}", fmt_row(&q))), v1_reply);

        // plan cleared: re-running the identical push converges to v2
        assert_eq!(ctl.push(&v2, true)[0].result, Ok(2));
        let status = ask("fleet-status");
        assert!(status.contains("champ@v2"), "{status}");
        assert_eq!(ask("shutdown"), "ok bye");
    });
    let _ = std::fs::remove_dir_all(&dir);
}

// ------------------------------------------------------- router.link

/// An injected `io` fault on the pooled replica link consumes exactly
/// one pooled link and one retry: the keyed forward still answers
/// (bit-identical to the unfaulted reply), the replica is NOT marked
/// dead, and the router keeps serving afterwards — a broken link is a
/// link problem, never a replica death.
#[test]
fn injected_router_link_fault_consumes_one_link_and_one_retry() {
    let _serial = serialize();
    let dir = scratch("router_link_io");
    let (model, q) = trained_model();
    let v1 = Artifact::wrap("champ", 1, &model, Provenance::default(), "lut", "auto").unwrap();

    let rl = TcpListener::bind("127.0.0.1:0").unwrap();
    let replica_addr = rl.local_addr().unwrap();
    let lr = TcpListener::bind("127.0.0.1:0").unwrap();
    let router_addr = lr.local_addr().unwrap();
    std::thread::scope(|s| {
        s.spawn(|| {
            let mut rep = ReplicaState::new(&dir).unwrap();
            let reg = ModelRegistry::new(Box::new(NativeBackend::new()), 7);
            serve_fleet(rl, reg, &ServeOptions::default(), &mut rep).unwrap();
        });
        let ropts = RouterOptions {
            seed: 42,
            vnodes: 64,
            timeout: Duration::from_secs(10),
            probe_every: Duration::from_secs(600),
            pool: 2,
            threads: 0,
        };
        let eps = vec![replica_addr.to_string()];
        let rh = s.spawn(move || run_router(lr, eps, &ropts).unwrap());
        let mut ctl = Controller::new(vec![replica_addr.to_string()], Duration::from_secs(10));
        assert_eq!(ctl.push(&v1, true)[0].result, Ok(1));

        let ask = |line: &str| {
            let c = TcpStream::connect(router_addr).unwrap();
            c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
            let mut w = c.try_clone().unwrap();
            w.write_all(format!("{line}\n").as_bytes()).unwrap();
            w.flush().unwrap();
            let mut r = BufReader::new(c);
            let mut reply = String::new();
            r.read_line(&mut reply).unwrap();
            reply.trim_end().to_string()
        };

        // warm: one clean forward opens the pooled link
        let query = format!("decision key=alice {}", fmt_row(&q));
        let baseline = ask(&query);
        assert!(baseline.starts_with("ok "), "{baseline}");

        {
            let _g = arm("router.link@1=io");
            // the faulted exchange breaks the warmed pooled link; the
            // router discards it and retries over a fresh dial
            assert_eq!(ask(&query), baseline, "the retried forward must answer identically");
            assert_eq!(fault::fired(), 1);
        }

        // telemetry pins the contract: one retry, zero replica deaths
        let stats = ask("router-stats");
        assert!(stats.starts_with("ok router "), "{stats}");
        assert!(stats.contains(" retries=1 "), "{stats}");
        assert!(stats.contains(" dead=0 "), "{stats}");

        // the router is not wedged: traffic keeps flowing after the fault
        assert_eq!(ask(&query), baseline);

        assert_eq!(ask("shutdown"), "ok bye");
        let report = rh.join().unwrap();
        assert_eq!(report.retried, 1, "exactly one retry");
        assert_eq!(report.replica_dead, 0, "link fault must not kill the replica");
        // warm dial + post-discard redial: the fault consumed one link
        assert_eq!(report.links_opened, 2, "one pooled link consumed, one redialed");
        assert_eq!(report.forwarded, 3);

        let c = TcpStream::connect(replica_addr).unwrap();
        let mut w = c.try_clone().unwrap();
        w.write_all(b"shutdown\n").unwrap();
        let mut reply = String::new();
        BufReader::new(c).read_line(&mut reply).unwrap();
        assert_eq!(reply.trim_end(), "ok bye");
    });
    let _ = std::fs::remove_dir_all(&dir);
}

/// A stalled pooled link (`stall:MS`) delays that one forward but the
/// request still answers correctly — slow links degrade latency, never
/// correctness, and the router never wedges.
#[test]
fn stalled_router_link_still_answers() {
    let _serial = serialize();
    let dir = scratch("router_link_stall");
    let (model, q) = trained_model();
    let v1 = Artifact::wrap("champ", 1, &model, Provenance::default(), "lut", "auto").unwrap();

    let rl = TcpListener::bind("127.0.0.1:0").unwrap();
    let replica_addr = rl.local_addr().unwrap();
    let lr = TcpListener::bind("127.0.0.1:0").unwrap();
    let router_addr = lr.local_addr().unwrap();
    std::thread::scope(|s| {
        s.spawn(|| {
            let mut rep = ReplicaState::new(&dir).unwrap();
            let reg = ModelRegistry::new(Box::new(NativeBackend::new()), 7);
            serve_fleet(rl, reg, &ServeOptions::default(), &mut rep).unwrap();
        });
        let eps = vec![replica_addr.to_string()];
        let rh = s.spawn(move || run_router(lr, eps, &RouterOptions::default()).unwrap());
        let mut ctl = Controller::new(vec![replica_addr.to_string()], Duration::from_secs(10));
        assert_eq!(ctl.push(&v1, true)[0].result, Ok(1));

        let ask = |line: &str| {
            let c = TcpStream::connect(router_addr).unwrap();
            c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
            let mut w = c.try_clone().unwrap();
            w.write_all(format!("{line}\n").as_bytes()).unwrap();
            w.flush().unwrap();
            let mut r = BufReader::new(c);
            let mut reply = String::new();
            r.read_line(&mut reply).unwrap();
            reply.trim_end().to_string()
        };

        let query = format!("decision key=alice {}", fmt_row(&q));
        let baseline = ask(&query);
        assert!(baseline.starts_with("ok "), "{baseline}");

        {
            let _g = arm("router.link@1=stall:120");
            let t0 = std::time::Instant::now();
            assert_eq!(ask(&query), baseline, "a stalled link must still answer");
            assert!(t0.elapsed() >= Duration::from_millis(120), "the stall must be real");
            assert_eq!(fault::fired(), 1);
        }

        assert_eq!(ask("shutdown"), "ok bye");
        let report = rh.join().unwrap();
        assert_eq!(report.retried, 0, "a stall is latency, not a failure");
        assert_eq!(report.replica_dead, 0);

        let c = TcpStream::connect(replica_addr).unwrap();
        let mut w = c.try_clone().unwrap();
        w.write_all(b"shutdown\n").unwrap();
        let mut reply = String::new();
        BufReader::new(c).read_line(&mut reply).unwrap();
        assert_eq!(reply.trim_end(), "ok bye");
    });
    let _ = std::fs::remove_dir_all(&dir);
}

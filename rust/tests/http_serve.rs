//! HTTP front-end acceptance (ISSUE 9): the HTTP/1.1 listener and the
//! TCP line protocol answer from the **same** engine, so the loopback
//! contract is bit-identity — a `POST /decision` body line answers the
//! exact reply string the line protocol's `decision` produces for the
//! same key and features on the native backend.  Also covered here:
//! `/metrics` exposition and `/healthz`, keep-alive framing, typed
//! 4xx/5xx mapping, and the shared-secret auth satellite (line
//! `auth <token>` handshake + HTTP `Authorization: Bearer`).

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::Duration;

use mmbsgd::config::TrainConfig;
use mmbsgd::data::synth::{dataset, SynthSpec};
use mmbsgd::model::SvmModel;
use mmbsgd::rng::Xoshiro256;
use mmbsgd::runtime::NativeBackend;
use mmbsgd::serve::{serve_bound, ModelRegistry, ServeOptions, ServeReport};
use mmbsgd::telemetry::Snapshot;

fn trained_model() -> SvmModel {
    let split = dataset(&SynthSpec::ijcnn_like(0.01), 2);
    let cfg = TrainConfig {
        lambda: 1e-3,
        gamma: 2.0,
        budget: 24,
        mergees: 3,
        seed: 41,
        epochs: 1,
        ..TrainConfig::default()
    };
    mmbsgd::solver::bsgd::train(&split.train, &cfg).unwrap().model
}

/// Run `serve_bound` with both listeners on loopback, drive it with
/// `client(line_addr, http_addr)` (which must trigger shutdown), and
/// return the client's result plus the server report.
fn serve_both<R: Send>(
    opts: ServeOptions,
    client: impl FnOnce(SocketAddr, SocketAddr) -> R + Send,
) -> (R, ServeReport) {
    let line_l = TcpListener::bind("127.0.0.1:0").unwrap();
    let http_l = TcpListener::bind("127.0.0.1:0").unwrap();
    let (la, ha) = (line_l.local_addr().unwrap(), http_l.local_addr().unwrap());
    let mut reg = ModelRegistry::new(Box::new(NativeBackend::new()), 1);
    reg.insert("m", trained_model()).unwrap();
    let mut out = None;
    let mut report = None;
    std::thread::scope(|s| {
        let h = s.spawn(move || client(la, ha));
        report = Some(serve_bound(line_l, Some(http_l), reg, &opts).unwrap());
        out = Some(h.join().unwrap());
    });
    (out.unwrap(), report.unwrap())
}

/// A line-protocol connection: one request line out, one reply in.
struct LineClient {
    rd: BufReader<TcpStream>,
    w: TcpStream,
}

impl LineClient {
    fn connect(addr: SocketAddr) -> Self {
        let c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        Self { rd: BufReader::new(c.try_clone().unwrap()), w: c }
    }

    fn ask(&mut self, line: &str) -> String {
        self.w.write_all(format!("{line}\n").as_bytes()).unwrap();
        self.w.flush().unwrap();
        let mut reply = String::new();
        self.rd.read_line(&mut reply).unwrap();
        reply.trim_end().to_string()
    }
}

/// A raw HTTP/1.1 connection speaking exactly what the front end
/// frames: Content-Length bodies, optional Bearer auth, keep-alive.
struct HttpClient {
    rd: BufReader<TcpStream>,
    w: TcpStream,
}

impl HttpClient {
    fn connect(addr: SocketAddr) -> Self {
        let c = TcpStream::connect(addr).unwrap();
        c.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
        Self { rd: BufReader::new(c.try_clone().unwrap()), w: c }
    }

    fn send_raw(&mut self, raw: &str) {
        self.w.write_all(raw.as_bytes()).unwrap();
        self.w.flush().unwrap();
    }

    /// Read one framed response; returns `(status, body)`.
    fn read_response(&mut self) -> (u16, String) {
        let mut line = String::new();
        assert!(self.rd.read_line(&mut line).unwrap() > 0, "server closed mid-response");
        let status: u16 =
            line.split_ascii_whitespace().nth(1).expect("status line").parse().unwrap();
        let mut content_length = 0usize;
        loop {
            let mut h = String::new();
            assert!(self.rd.read_line(&mut h).unwrap() > 0, "server closed mid-headers");
            let t = h.trim();
            if t.is_empty() {
                break;
            }
            let lower = t.to_ascii_lowercase();
            if let Some(v) = lower.strip_prefix("content-length:") {
                content_length = v.trim().parse().unwrap();
            }
        }
        let mut body = vec![0u8; content_length];
        self.rd.read_exact(&mut body).unwrap();
        (status, String::from_utf8(body).unwrap())
    }

    fn get(&mut self, path: &str, bearer: Option<&str>) -> (u16, String) {
        let auth =
            bearer.map(|t| format!("Authorization: Bearer {t}\r\n")).unwrap_or_default();
        self.send_raw(&format!("GET {path} HTTP/1.1\r\n{auth}\r\n"));
        self.read_response()
    }

    fn post(&mut self, path: &str, body: &str, bearer: Option<&str>) -> (u16, String) {
        let auth =
            bearer.map(|t| format!("Authorization: Bearer {t}\r\n")).unwrap_or_default();
        self.send_raw(&format!(
            "POST {path} HTTP/1.1\r\nContent-Length: {}\r\n{auth}\r\n{body}",
            body.len()
        ));
        self.read_response()
    }
}

/// Deterministic keyed request argument lines (`key=kI f1 f2 ...`).
fn request_lines(dim: usize, n: usize) -> Vec<String> {
    let mut rng = Xoshiro256::new(907);
    (0..n)
        .map(|i| {
            let feats: Vec<String> =
                (0..dim).map(|_| format!("{:.4}", rng.next_f64() * 2.0 - 1.0)).collect();
            format!("key=k{i} {}", feats.join(" "))
        })
        .collect()
}

/// The loopback acceptance criterion: HTTP-batched answers are
/// bit-identical strings to line-protocol `decision` answers for the
/// same keys on the native backend — same parse, same engine, same
/// reply formatting, so equality is exact, not approximate.
#[test]
fn http_decision_replies_bit_identical_to_line_protocol() {
    let dim = trained_model().svs.dim();
    let lines = request_lines(dim, 8);
    let ((via_line, status, via_http), _report) =
        serve_both(ServeOptions::default(), move |la, ha| {
            let mut lc = LineClient::connect(la);
            let via_line: Vec<String> =
                lines.iter().map(|l| lc.ask(&format!("decision {l}"))).collect();
            let mut hc = HttpClient::connect(ha);
            let body = format!("{}\n", lines.join("\n"));
            let (status, http_body) = hc.post("/decision", &body, None);
            let via_http: Vec<String> =
                http_body.lines().map(|l| l.to_string()).collect();
            assert_eq!(lc.ask("shutdown"), "ok bye");
            (via_line, status, via_http)
        });
    assert_eq!(status, 200);
    assert_eq!(via_line.len(), 8);
    assert_eq!(via_http, via_line, "HTTP and line protocol replies must be bit-identical");
    for reply in &via_line {
        assert!(reply.starts_with("ok "), "{reply}");
        assert!(reply.contains("m@v1"), "decision names model@version: {reply}");
    }
}

/// `/healthz`, `/metrics` exposition (parseable, carrying both source
/// counters and engine mirrors), keep-alive across requests on one
/// connection, and `Connection: close` honored.
#[test]
fn metrics_healthz_and_keepalive() {
    let dim = trained_model().svs.dim();
    let lines = request_lines(dim, 3);
    let ((health, predict_status, scrape), report) =
        serve_both(ServeOptions::default(), move |la, ha| {
            // one keep-alive connection carries all three requests
            let mut hc = HttpClient::connect(ha);
            let (hs, health) = hc.get("/healthz", None);
            assert_eq!(hs, 200);
            let body = format!("{}\n", lines.join("\n"));
            let (predict_status, preds) = hc.post("/predict", &body, None);
            assert_eq!(preds.lines().count(), 3);
            // The engine republishes its mirror counters after each
            // burst, *after* the replies are already out — poll the
            // scrape until the mirror catches up (at most one burst).
            let mut scrape = String::new();
            for _ in 0..200 {
                let (ms, text) = hc.get("/metrics", None);
                assert_eq!(ms, 200);
                scrape = text;
                if scrape.contains("serve_engine_served_total 3") {
                    break;
                }
                std::thread::sleep(Duration::from_millis(10));
            }
            // Connection: close is honored: the server answers, then EOF
            hc.send_raw("GET /healthz HTTP/1.0\r\n\r\n");
            let (cs, _) = hc.read_response();
            assert_eq!(cs, 200);
            let mut rest = String::new();
            assert_eq!(hc.rd.read_line(&mut rest).unwrap(), 0, "HTTP/1.0 closes");
            let mut lc = LineClient::connect(la);
            assert_eq!(lc.ask("shutdown"), "ok bye");
            (health, predict_status, scrape)
        });
    assert_eq!(health, "ok\n");
    assert_eq!(predict_status, 200);
    let snap = Snapshot::parse(&scrape).expect("exposition text parses back");
    assert_eq!(snap.counters["serve_http_connections_total"], 1);
    // healthz + predict answered before the scrape rendered
    assert!(snap.counters["serve_http_requests_total"] >= 2, "{scrape}");
    assert_eq!(snap.counters["serve_engine_served_total"], 3, "predict rows mirrored");
    assert!(snap.gauges.contains_key("serve_window_accuracy"), "{scrape}");
    let lat = &snap.histograms["serve_http_request_ns"];
    assert!(lat.count >= 2, "request latency observed");
    // the line `stats` view and the scrape share the same counters
    assert_eq!(report.engine.served, 3);
}

/// Typed rejections: bad method, missing Content-Length, oversized
/// declared body, unknown route, malformed request body line, and the
/// engine's unknown-model mapping.
#[test]
fn http_rejections_map_to_typed_statuses() {
    let opts = ServeOptions { max_body_bytes: 256, ..ServeOptions::default() };
    let ((), _report) = serve_both(opts, move |la, ha| {
        // head-level rejections close the connection: one client each
        let (s, body) = {
            let mut hc = HttpClient::connect(ha);
            hc.send_raw("DELETE /metrics HTTP/1.1\r\n\r\n");
            hc.read_response()
        };
        assert_eq!((s, body.contains("not allowed")), (405, true), "{body}");
        let (s, _) = {
            let mut hc = HttpClient::connect(ha);
            hc.send_raw("POST /decision HTTP/1.1\r\n\r\n");
            hc.read_response()
        };
        assert_eq!(s, 411, "POST without Content-Length");
        let (s, _) = {
            let mut hc = HttpClient::connect(ha);
            hc.send_raw("POST /decision HTTP/1.1\r\nContent-Length: 99999\r\n\r\n");
            hc.read_response()
        };
        assert_eq!(s, 413, "declared body over max_body_bytes");
        // request-level rejections keep the connection alive
        let mut hc = HttpClient::connect(ha);
        let (s, _) = hc.get("/nope", None);
        assert_eq!(s, 404);
        let (s, body) = hc.post("/decision", "not-a-number\n", None);
        assert_eq!(s, 400, "malformed body line: {body}");
        assert!(body.starts_with("err "), "{body}");
        let (s, _) = hc.get("/healthz", None);
        assert_eq!(s, 200, "connection survived the 400");
        let mut lc = LineClient::connect(la);
        assert_eq!(lc.ask("shutdown"), "ok bye");
    });
}

/// Shared-secret auth on both surfaces: the line protocol demands an
/// `auth <token>` first line (wrong/missing token answers
/// `err unauthorized` and closes), HTTP demands a Bearer header (401).
/// Authenticated traffic flows normally on both.
#[test]
fn auth_token_gates_both_surfaces() {
    let dim = trained_model().svs.dim();
    let line = request_lines(dim, 1).remove(0);
    let opts = ServeOptions { auth_token: "sesame".into(), ..ServeOptions::default() };
    let ((), report) = serve_both(opts, move |la, ha| {
        // line protocol, no handshake: typed refusal then EOF
        let mut bad = LineClient::connect(la);
        let refusal = bad.ask(&format!("decision {line}"));
        assert!(refusal.starts_with("err unauthorized"), "{refusal}");
        let mut rest = String::new();
        assert_eq!(bad.rd.read_line(&mut rest).unwrap(), 0, "connection closes after refusal");
        // wrong token: same refusal
        let mut wrong = LineClient::connect(la);
        assert!(wrong.ask("auth opensaysme").starts_with("err unauthorized"));
        // HTTP, no/wrong bearer: 401, body names the error
        let (s, body) = HttpClient::connect(ha).get("/metrics", None);
        assert_eq!(s, 401);
        assert!(body.starts_with("unauthorized"), "{body}");
        let (s, _) = HttpClient::connect(ha).get("/metrics", Some("opensaysme"));
        assert_eq!(s, 401);
        // authenticated traffic flows on both surfaces
        let mut lc = LineClient::connect(la);
        assert_eq!(lc.ask("auth sesame"), "ok authed");
        assert!(lc.ask(&format!("decision {line}")).starts_with("ok "));
        let mut hc = HttpClient::connect(ha);
        let (s, got) = hc.post("/decision", &format!("{line}\n"), Some("sesame"));
        assert_eq!(s, 200);
        assert!(got.starts_with("ok "), "{got}");
        let (s, scrape) = hc.get("/metrics", Some("sesame"));
        assert_eq!(s, 200);
        let snap = Snapshot::parse(&scrape).unwrap();
        assert!(snap.counters["serve_auth_failures_total"] >= 4, "{scrape}");
        assert_eq!(lc.ask("shutdown"), "ok bye");
    });
    assert_eq!(report.engine.served, 2, "one line decision + one http decision");
}

//! Tile-engine acceptance tests (ISSUE 3 tentpole):
//!
//! 1. **Blocked-vs-scalar parity** — `Backend::margins` through the
//!    cache-blocked tile engine agrees with the scalar
//!    `margin1_native` loop on every ragged shape (the engine is
//!    designed to be bit-identical; the gate here is the 1e-12 spec).
//! 2. **Thread invariance** — a full `train_full` run and a
//!    `merge_scores_batch` pass produce identical *bits* for
//!    `threads ∈ {1, 2, 4}`: the pool's fixed partition and j-ordered
//!    accumulation make the worker count a pure wall-clock knob.
//! 3. **`EXP_NEG_CUTOFF` boundary** — the fused far-pair skip changes
//!    the margin by no more than the sub-`e⁻⁴⁰` mass it drops (1e-15
//!    gate), exactly at the cutoff boundary where it matters.

use mmbsgd::config::TrainConfig;
use mmbsgd::data::synth::{dataset, SynthSpec};
use mmbsgd::data::DenseMatrix;
use mmbsgd::kernel::{sq_dist_cached, sq_norm, EXP_NEG_CUTOFF};
use mmbsgd::model::SvStore;
use mmbsgd::rng::Xoshiro256;
use mmbsgd::runtime::{margin1_native, Backend, NativeBackend};
use mmbsgd::solver::bsgd;
use mmbsgd::solver::NoopObserver;

fn random_store(b: usize, d: usize, seed: u64) -> SvStore {
    let mut rng = Xoshiro256::new(seed);
    let mut s = SvStore::new(d);
    // Spread over near and far pairs so both exp branches run.
    let scale = if d > 0 { (5.0 / d as f64).sqrt() as f32 } else { 1.0 };
    for j in 0..b {
        let shift = if j % 3 == 0 { 4.0f32 } else { 0.0 };
        let x: Vec<f32> = (0..d)
            .map(|_| shift + scale * rng.next_gaussian() as f32)
            .collect();
        let mut a = 0.05 + rng.next_f64();
        if rng.next_f64() < 0.5 {
            a = -a;
        }
        s.push(&x, a);
    }
    s
}

fn random_queries(n: usize, d: usize, seed: u64) -> DenseMatrix {
    let mut rng = Xoshiro256::new(seed);
    DenseMatrix::from_rows(
        (0..n)
            .map(|_| (0..d).map(|_| 2.0 * rng.next_gaussian() as f32).collect())
            .collect(),
    )
}

#[test]
fn blocked_margins_match_scalar_over_ragged_shapes() {
    let gamma = 0.8;
    for &b in &[0usize, 1, 7, 64, 513] {
        for &d in &[1usize, 3, 300] {
            let svs = random_store(b, d, (b * 1000 + d) as u64 + 1);
            for &n in &[1usize, 33, 100] {
                let q = random_queries(n, d, (n + d) as u64);
                for threads in [1usize, 3] {
                    let mut be = NativeBackend::new();
                    assert_eq!(be.set_threads(threads), threads);
                    let got = be.margins(&svs, gamma, &q);
                    assert_eq!(got.len(), n);
                    for r in 0..n {
                        let want = margin1_native(&svs, gamma, q.row(r));
                        assert!(
                            (got[r] - want).abs() <= 1e-12,
                            "B={b} d={d} n={n} t={threads} row {r}: {} vs {}",
                            got[r],
                            want
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn train_full_is_bit_identical_across_thread_counts() {
    let split = dataset(&SynthSpec::ijcnn_like(0.02), 11);
    let run = |threads: usize| {
        let cfg = TrainConfig {
            lambda: 1e-3,
            gamma: 2.0,
            budget: 24,
            mergees: 3,
            eval_every: 150, // exercise the threaded eval margins too
            threads,
            seed: 7,
            ..TrainConfig::default()
        };
        let mut be = NativeBackend::new();
        bsgd::train_full(&split.train, &cfg, &mut be, Some(&split.test), &mut NoopObserver)
            .unwrap()
    };
    let base = run(1);
    assert!(base.maintenance_events > 0, "budget never hit — test is vacuous");
    for threads in [2usize, 4] {
        let out = run(threads);
        assert_eq!(out.steps, base.steps, "threads={threads}");
        assert_eq!(out.margin_violations, base.margin_violations);
        assert_eq!(out.maintenance_events, base.maintenance_events);
        assert_eq!(out.model.svs.points_flat(), base.model.svs.points_flat());
        let (a, b) = (out.model.svs.alphas_vec(), base.model.svs.alphas_vec());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "alpha drift at threads={threads}");
        }
        assert_eq!(out.model.bias.to_bits(), base.model.bias.to_bits());
        assert_eq!(
            out.total_weight_degradation.to_bits(),
            base.total_weight_degradation.to_bits()
        );
        // the eval-history hook ran through the threaded tile engine
        assert_eq!(out.history.len(), base.history.len());
        for (p, q) in out.history.iter().zip(&base.history) {
            assert_eq!(p.accuracy.to_bits(), q.accuracy.to_bits());
            assert_eq!(p.n_svs, q.n_svs);
        }
    }
}

#[test]
fn merge_scores_batch_is_bit_identical_across_thread_counts() {
    let svs = random_store(400, 24, 21);
    let cands = [0usize, 17, 203, 399];
    let score = |threads: usize| {
        let mut be = NativeBackend::new();
        be.set_threads(threads);
        be.merge_scores_batch(&svs, 1.3, &cands)
    };
    let base = score(1);
    for threads in [2usize, 4] {
        let got = score(threads);
        for (c, (a, b)) in got.iter().zip(&base).enumerate() {
            for lane in 0..svs.len() {
                assert_eq!(a.wd[lane].to_bits(), b.wd[lane].to_bits(), "c{c} lane{lane}");
                assert_eq!(a.h[lane].to_bits(), b.h[lane].to_bits());
                assert_eq!(a.a_z[lane].to_bits(), b.a_z[lane].to_bits());
                assert_eq!(a.d2[lane].to_bits(), b.d2[lane].to_bits());
            }
        }
    }
    // and the batch rows equal the per-event scorer they stand in for
    let mut be = NativeBackend::new();
    for (c, &i) in cands.iter().enumerate() {
        let single = be.merge_scores(&svs, 1.3, i);
        for lane in 0..svs.len() {
            assert_eq!(base[c].wd[lane].to_bits(), single.wd[lane].to_bits());
            assert_eq!(base[c].d2[lane].to_bits(), single.d2[lane].to_bits());
        }
    }
}

#[test]
fn exp_cutoff_skip_agrees_with_unskipped_sum_at_the_boundary() {
    // SVs placed so γd² brackets EXP_NEG_CUTOFF = 40 from both sides
    // (the exact regime the skip decision discriminates), plus a few
    // nearby SVs carrying real signal.  The unskipped reference sums
    // every term; the hot-path margin may drop only sub-e⁻⁴⁰ mass.
    let gamma = 1.0;
    let d = 4;
    let mut svs = SvStore::new(d);
    let mut rng = Xoshiro256::new(99);
    for k in 0..64 {
        // radius sweep: d² ∈ [38, 42] ⇒ γd² straddles the cutoff
        let d2_target = 38.0 + 4.0 * (k as f64 / 63.0);
        let r = (d2_target / d as f64).sqrt() as f32;
        let x = [r, r, r, r];
        let mut a = 0.2 + 0.8 * rng.next_f64();
        if k % 2 == 0 {
            a = -a;
        }
        svs.push(&x, a);
    }
    for _ in 0..8 {
        let x: Vec<f32> = (0..d).map(|_| rng.next_gaussian() as f32 * 0.5).collect();
        svs.push(&x, 0.5 + rng.next_f64());
    }

    let queries = random_queries(16, d, 7);
    let mut rows = vec![vec![0.0f32; d]]; // the exact straddle point
    for r in 0..queries.rows() {
        rows.push(queries.row(r).to_vec());
    }
    let q = DenseMatrix::from_rows(rows);

    let mut be = NativeBackend::new();
    let got = be.margins(&svs, gamma, &q);
    for r in 0..q.rows() {
        let x = q.row(r);
        let n_q = sq_norm(x);
        // Unskipped reference: identical distance arithmetic, no cutoff.
        let mut want = 0.0;
        let mut dropped_bound = 0.0;
        for j in 0..svs.len() {
            let d2 = sq_dist_cached(svs.point(j), svs.norm2(j), x, n_q);
            let e = gamma * d2;
            want += svs.alpha(j) * (-e).exp();
            if e >= EXP_NEG_CUTOFF {
                dropped_bound += svs.alpha(j).abs() * (-EXP_NEG_CUTOFF).exp();
            }
        }
        let diff = (got[r] - want).abs();
        assert!(
            diff <= 1e-15,
            "row {r}: skip drift {diff:.3e} (bound {dropped_bound:.3e})"
        );
        // sanity: the property is non-vacuous — the skipped mass is
        // really below the gate, not merely never skipped
        assert!(dropped_bound <= 1e-15, "test geometry drifted: {dropped_bound:.3e}");
    }
}

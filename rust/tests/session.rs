//! Session-surface integration tests: streaming ingestion, typed entry
//! errors, checkpoint/resume bit-identity, and the serving handle.
//!
//! The central contract (ISSUE 2 acceptance): a run interrupted at any
//! step t and resumed from its checkpoint — fresh session, fresh
//! backend — must produce *bit-identical* final support vectors, bias,
//! and maintenance statistics to an uninterrupted run with the same
//! seed.  That requires the checkpoint to capture the RNG state, the
//! lazy coefficient scale unfolded, the budget counters, and the
//! unconsumed remainder of the in-flight epoch; each is exercised here.

use mmbsgd::config::TrainConfig;
use mmbsgd::data::synth::{dataset, SynthSpec};
use mmbsgd::data::{DenseMatrix, Split};
use mmbsgd::error::TrainError;
use mmbsgd::runtime::NativeBackend;
use mmbsgd::serve::Predictor;
use mmbsgd::solver::bsgd::{self, TrainOutput};
use mmbsgd::solver::{load_checkpoint, Checkpoint, NoopObserver, TrainSession};
use mmbsgd::util::durable;
use std::path::PathBuf;

fn tiny_split() -> Split {
    dataset(&SynthSpec::ijcnn_like(0.02), 11) // ~1000 points, d=22
}

fn tiny_cfg(epochs: usize) -> TrainConfig {
    TrainConfig {
        lambda: 1e-3,
        gamma: 2.0,
        budget: 32,
        mergees: 3,
        epochs,
        seed: 7,
        ..TrainConfig::default()
    }
}

/// Train to completion through the batch wrapper.
fn reference_run(split: &Split, cfg: &TrainConfig) -> TrainOutput {
    bsgd::train(&split.train, cfg).unwrap()
}

/// Train with an interruption (checkpoint + resume) at step `t`.
fn interrupted_run(split: &Split, cfg: &TrainConfig, t: u64) -> TrainOutput {
    let mut be = NativeBackend::new();
    let mut sess = TrainSession::new(cfg.clone(), &mut be).unwrap();
    let mut remaining = t;
    while remaining > 0 && sess.epochs_done() < cfg.epochs as u64 {
        let before = sess.steps();
        sess.run_epoch(&split.train, None, &mut NoopObserver, remaining).unwrap();
        remaining -= sess.steps() - before;
    }
    assert_eq!(sess.steps(), t.min((split.train.len() * cfg.epochs) as u64));
    let blob = sess.checkpoint();
    drop(sess);

    let mut be2 = NativeBackend::new();
    let mut resumed = TrainSession::resume(&blob, &mut be2).unwrap();
    while resumed.epochs_done() < cfg.epochs as u64 {
        resumed.partial_fit(&split.train).unwrap();
    }
    resumed.finish()
}

fn assert_bit_identical(a: &TrainOutput, b: &TrainOutput) {
    assert_eq!(a.steps, b.steps);
    assert_eq!(a.margin_violations, b.margin_violations);
    assert_eq!(a.maintenance_events, b.maintenance_events);
    assert_eq!(
        a.total_weight_degradation.to_bits(),
        b.total_weight_degradation.to_bits(),
        "Σwd diverged: {} vs {}",
        a.total_weight_degradation,
        b.total_weight_degradation
    );
    assert_eq!(a.model.svs.len(), b.model.svs.len());
    assert_eq!(a.model.svs.points_flat(), b.model.svs.points_flat());
    let (aa, ba) = (a.model.svs.alphas_vec(), b.model.svs.alphas_vec());
    for (x, y) in aa.iter().zip(&ba) {
        assert_eq!(x.to_bits(), y.to_bits(), "alpha diverged: {x} vs {y}");
    }
    assert_eq!(a.model.bias.to_bits(), b.model.bias.to_bits());
}

#[test]
fn resume_is_bit_identical_at_many_interrupt_points() {
    let split = tiny_split();
    let cfg = tiny_cfg(1);
    let reference = reference_run(&split, &cfg);
    let n = split.train.len() as u64;
    // early, mid, late, and one step before the end
    for t in [1, 7, n / 2, n - 1] {
        let resumed = interrupted_run(&split, &cfg, t);
        assert_bit_identical(&reference, &resumed);
    }
}

#[test]
fn resume_across_epoch_boundary_is_bit_identical() {
    let split = tiny_split();
    let cfg = tiny_cfg(2);
    let reference = reference_run(&split, &cfg);
    let n = split.train.len() as u64;
    // exactly at the boundary (epoch 1 complete) and mid-epoch-two:
    // both depend on the serialized RNG stream for epoch two's shuffle
    for t in [n, n + n / 3] {
        let resumed = interrupted_run(&split, &cfg, t);
        assert_bit_identical(&reference, &resumed);
    }
}

#[test]
fn double_interruption_still_bit_identical() {
    // checkpoint → resume → checkpoint → resume: state must survive
    // arbitrary chaining, not just one hop
    let split = tiny_split();
    let cfg = tiny_cfg(1);
    let reference = reference_run(&split, &cfg);

    let mut be = NativeBackend::new();
    let mut s1 = TrainSession::new(cfg.clone(), &mut be).unwrap();
    s1.run_epoch(&split.train, None, &mut NoopObserver, 100).unwrap();
    let blob1 = s1.checkpoint();
    let mut be2 = NativeBackend::new();
    let mut s2 = TrainSession::resume(&blob1, &mut be2).unwrap();
    s2.run_epoch(&split.train, None, &mut NoopObserver, 250).unwrap();
    let blob2 = s2.checkpoint();
    let mut be3 = NativeBackend::new();
    let mut s3 = TrainSession::resume(&blob2, &mut be3).unwrap();
    s3.partial_fit(&split.train).unwrap();
    assert_bit_identical(&reference, &s3.finish());
}

#[test]
fn train_full_equals_manual_session_loop() {
    // the wrapper must add nothing: same stream, same model
    let split = tiny_split();
    let cfg = tiny_cfg(1);
    let wrapped = reference_run(&split, &cfg);

    let mut be = NativeBackend::new();
    let mut sess = TrainSession::new(cfg.clone(), &mut be).unwrap();
    sess.partial_fit(&split.train).unwrap();
    assert_bit_identical(&wrapped, &sess.finish());
}

#[test]
fn checkpoint_captures_eval_history_and_times() {
    let split = tiny_split();
    let mut cfg = tiny_cfg(1);
    cfg.eval_every = 100;
    let mut be = NativeBackend::new();
    let mut sess = TrainSession::new(cfg, &mut be).unwrap();
    sess.run_epoch(&split.train, Some(&split.test), &mut NoopObserver, 450).unwrap();
    let n_points = sess.history().len();
    assert_eq!(n_points, 4, "eval_every=100 over 450 steps");
    let blob = sess.checkpoint();

    let mut be2 = NativeBackend::new();
    let mut resumed = TrainSession::resume(&blob, &mut be2).unwrap();
    assert_eq!(resumed.history().len(), n_points);
    assert!(resumed.times().get("margin").as_secs_f64() > 0.0);
    resumed.run_epoch(&split.train, Some(&split.test), &mut NoopObserver, 0).unwrap();
    let out = resumed.finish();
    assert!(out.history.len() > n_points);
    // curve steps strictly increasing across the interruption
    assert!(out.history.windows(2).all(|w| w[0].step < w[1].step));
}

#[test]
fn session_rejects_bad_inputs_with_typed_errors() {
    let mut be = NativeBackend::new();
    // invalid config
    let mut cfg = tiny_cfg(1);
    cfg.mergees = 99;
    assert!(matches!(
        TrainSession::new(cfg, &mut be).err().unwrap(),
        TrainError::InvalidConfig { field: "mergees", .. }
    ));
    // unresolved C
    let mut cfg = tiny_cfg(1);
    cfg.cost_c = Some(4.0);
    assert!(matches!(
        TrainSession::new(cfg, &mut be).err().unwrap(),
        TrainError::UnresolvedCost { .. }
    ));
    // wrapper surfaces the same errors instead of panicking
    let split = tiny_split();
    let mut cfg = tiny_cfg(1);
    cfg.gamma = -1.0;
    assert!(bsgd::train(&split.train, &cfg).is_err());
}

#[test]
fn checkpoint_parse_rejects_tampering() {
    let split = tiny_split();
    let mut be = NativeBackend::new();
    let mut sess = TrainSession::new(tiny_cfg(1), &mut be).unwrap();
    sess.run_epoch(&split.train, None, &mut NoopObserver, 50).unwrap();
    let blob = sess.checkpoint();

    // parses clean
    assert!(Checkpoint::parse(&blob).is_ok());
    // every prefix-truncation fails with a typed error, never a panic
    for frac in [1, 3, 10, 50, 90] {
        let cut = &blob[..blob.len() * frac / 100];
        match Checkpoint::parse(cut) {
            Err(TrainError::Checkpoint(_)) => {}
            Ok(_) => panic!("truncated blob at {frac}% parsed"),
            Err(e) => panic!("wrong error kind: {e}"),
        }
    }
    // corrupted numeric field
    let broken = blob.replacen("rng ", "rng x", 1);
    assert!(matches!(Checkpoint::parse(&broken), Err(TrainError::Checkpoint(_))));
}

fn scratch(tag: &str) -> PathBuf {
    let dir =
        std::env::temp_dir().join(format!("mmbsgd_session_{tag}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Run a fresh session up to step `t` and return its checkpoint blob.
fn blob_at(split: &Split, cfg: &TrainConfig, t: u64) -> String {
    let mut be = NativeBackend::new();
    let mut sess = TrainSession::new(cfg.clone(), &mut be).unwrap();
    let mut remaining = t;
    while remaining > 0 && sess.epochs_done() < cfg.epochs as u64 {
        let before = sess.steps();
        sess.run_epoch(&split.train, None, &mut NoopObserver, remaining).unwrap();
        remaining -= sess.steps() - before;
    }
    sess.checkpoint()
}

/// Attach a loaded checkpoint to a fresh backend and train to the end.
fn finish_from(ck: Checkpoint, split: &Split, epochs: usize) -> TrainOutput {
    let mut be = NativeBackend::new();
    let mut sess = ck.into_session(&mut be).unwrap();
    while sess.epochs_done() < epochs as u64 {
        sess.partial_fit(&split.train).unwrap();
    }
    sess.finish()
}

/// The kill-point fault matrix (ISSUE 6 acceptance): at several
/// checkpoint boundaries, write two durable generations, destroy the
/// primary in every way a torn or corrupted write can (truncation
/// before and after the footer, a flipped payload byte, deleted middle
/// bytes, an emptied file, a deleted file), and assert that resume
/// falls back to the intact `.prev` generation and finishes
/// bit-identical to an uninterrupted run.
#[test]
fn corrupted_primary_checkpoint_falls_back_to_prev_bit_identically() {
    let split = tiny_split();
    let cfg = tiny_cfg(1);
    let reference = reference_run(&split, &cfg);
    let n = split.train.len() as u64;
    let dir = scratch("fault_matrix");
    let path = dir.join("ck.txt");

    type Corruptor = fn(&str) -> Option<String>;
    // `None` means "delete the primary file".
    let corruptions: [(&str, Corruptor); 6] = [
        ("truncate-40pc", |s| Some(s[..s.len() * 2 / 5].to_string())),
        ("truncate-last-3", |s| Some(s[..s.len() - 3].to_string())),
        ("flip-digit", |s| {
            let i = s.find(|c: char| c.is_ascii_digit()).expect("blob has digits");
            let mut b = s.as_bytes().to_vec();
            b[i] = if b[i] == b'9' { b'0' } else { b[i] + 1 };
            Some(String::from_utf8(b).unwrap())
        }),
        ("delete-middle", |s| {
            let (a, b) = (s.len() / 3, s.len() / 2);
            Some(format!("{}{}", &s[..a], &s[b..]))
        }),
        ("empty", |_| Some(String::new())),
        ("delete-file", |_| None),
    ];

    for t in [n / 4, n / 2, 3 * n / 4] {
        let early = blob_at(&split, &cfg, t / 2); // becomes .prev
        let late = blob_at(&split, &cfg, t); // becomes the primary
        for (name, corrupt) in &corruptions {
            durable::write_atomic(&path, &early).unwrap();
            durable::write_atomic(&path, &late).unwrap(); // rotates early → .prev
            let text = std::fs::read_to_string(&path).unwrap();
            match corrupt(&text) {
                Some(bad) => std::fs::write(&path, bad).unwrap(),
                None => std::fs::remove_file(&path).unwrap(),
            }
            let loaded = load_checkpoint(&path)
                .unwrap_or_else(|e| panic!("{name} at t={t}: no fallback: {e}"));
            assert_eq!(
                loaded.generation,
                durable::Generation::Prev,
                "{name} at t={t} must reject the primary"
            );
            assert!(loaded.primary_error.is_some(), "{name} at t={t}");
            let out = finish_from(loaded.checkpoint, &split, cfg.epochs);
            assert_bit_identical(&reference, &out);
            let _ = std::fs::remove_file(&path);
            let _ = std::fs::remove_file(durable::prev_path(&path));
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

/// An intact primary resumes as `Primary` (no spurious fallback), and
/// a corrupt primary with no `.prev` fails with the typed
/// `CorruptCheckpoint` that says no fallback exists.
#[test]
fn checkpoint_load_reports_generation_and_missing_fallback() {
    let split = tiny_split();
    let cfg = tiny_cfg(1);
    let reference = reference_run(&split, &cfg);
    let dir = scratch("no_prev");
    let path = dir.join("ck.txt");

    let blob = blob_at(&split, &cfg, split.train.len() as u64 / 3);
    durable::write_atomic(&path, &blob).unwrap();
    let loaded = load_checkpoint(&path).unwrap();
    assert_eq!(loaded.generation, durable::Generation::Primary);
    assert!(loaded.primary_error.is_none());
    let out = finish_from(loaded.checkpoint, &split, cfg.epochs);
    assert_bit_identical(&reference, &out);

    // first-ever write (no .prev yet) corrupted: a typed error naming
    // the failing section and the absence of a fallback — never a panic
    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::write(&path, text.replacen("step", "stop", 1)).unwrap();
    match load_checkpoint(&path) {
        Err(TrainError::CorruptCheckpoint { prev_exists, section, .. }) => {
            assert!(!prev_exists, "no .prev was ever written");
            assert!(!section.is_empty());
        }
        Err(e) => panic!("wrong error kind: {e}"),
        Ok(_) => panic!("corrupt primary with no fallback must not load"),
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn predictor_serves_trained_and_reloaded_models() {
    let split = tiny_split();
    let out = reference_run(&split, &tiny_cfg(1));
    let text = out.model.to_text();

    let mut live = Predictor::native(out.model).unwrap();
    let reloaded_model = mmbsgd::model::SvmModel::from_text(&text).unwrap();
    let mut reloaded = Predictor::native(reloaded_model).unwrap();

    let acc_live = live.accuracy(&split.test).unwrap();
    let acc_reload = reloaded.accuracy(&split.test).unwrap();
    assert!(acc_live > 0.8, "served accuracy {acc_live}");
    assert_eq!(acc_live, acc_reload, "save/load must not change served predictions");

    // batched and single-point paths agree
    let q = DenseMatrix::from_rows(vec![split.test.x.row(0).to_vec()]);
    let batch = live.decision_batch(&q).unwrap();
    let single = live.decision1(split.test.x.row(0)).unwrap();
    assert!((batch[0] - single).abs() < 1e-12);

    // shape errors are typed
    assert!(matches!(
        live.decision_batch(&DenseMatrix::zeros(2, 5)).unwrap_err(),
        TrainError::DimMismatch { .. }
    ));
}

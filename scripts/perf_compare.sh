#!/usr/bin/env bash
# Threshold-gated perf comparison against the committed baseline.
#
# Usage:
#   scripts/perf_compare.sh [CURRENT] [BASELINE]
#   scripts/perf_compare.sh --render OUT.md [CURRENT]
#
# CURRENT  defaults to rust/BENCH_hotpaths.json (what `cargo bench
#          --bench hot_paths` just wrote, CI runs from rust/).
# BASELINE defaults to BENCH_hotpaths.json (the committed floor at the
#          repo root — the perf trajectory as a tracked artifact).
#
# Compare mode gates every `speedup/*` row the baseline commits to:
#   - a row missing from CURRENT is a failure (the bench stopped
#     running is itself a regression of the evidence);
#   - current < baseline * (1 - MMBSGD_PERF_TOLERANCE) is a failure
#     (default tolerance 0.20, i.e. a >20% regression of a committed
#     speedup ratio fails the build).
# MMBSGD_PERF_WARN_ONLY=1 downgrades failures to warnings (escape
# hatch for known-noisy runners); the diff is always printed.
#
# Serve artifacts (`mmbsgd loadgen` output, e.g. BENCH_serve.json):
# when CURRENT carries `serve/*` rows and no `speedup/*` rows, the
# baseline speedup diff is skipped and the rows are sanity-gated
# instead (latencies positive and ordered, rates in [0,1], positive
# throughput) — the artifact proves the serve path ran, the absolute
# numbers are machine-dependent.
#
# Render mode writes the perf.md speedup table from CURRENT.
set -euo pipefail

cd "$(dirname "$0")/.."

MODE=compare
OUT=""
if [ "${1:-}" = "--render" ]; then
    MODE=render
    OUT="${2:?--render needs an output path}"
    CURRENT="${3:-rust/BENCH_hotpaths.json}"
    BASELINE=""
else
    CURRENT="${1:-rust/BENCH_hotpaths.json}"
    BASELINE="${2:-BENCH_hotpaths.json}"
fi

MODE="$MODE" OUT="$OUT" CURRENT="$CURRENT" BASELINE="$BASELINE" python3 - <<'PY'
import json, os, sys

mode = os.environ["MODE"]
current_path = os.environ["CURRENT"]

def load(path):
    with open(path) as f:
        doc = json.load(f)
    if doc.get("schema") != "mmbsgd-bench-v1":
        sys.exit(f"{path}: unexpected schema {doc.get('schema')!r}")
    return {d["name"]: d["value"] for d in doc.get("derived", [])}

current = load(current_path)

if mode == "render":
    out = os.environ["OUT"]
    lines = [
        "# Perf trajectory",
        "",
        "Committed speedup floors for the hot paths, regenerated from",
        f"`{current_path}` by `scripts/perf_compare.sh --render`.  CI fails",
        "when any `speedup/*` ratio regresses more than 20% below the",
        "committed `BENCH_hotpaths.json` baseline (see that file's `note`",
        "for provenance).  Absolute numbers are machine-dependent; the",
        "ratios are the contract.",
        "",
        "Serve- and router-path latency evidence travels separately:",
        "CI's loadgen smokes (`mmbsgd loadgen --mode http` and",
        "`--mode router`) upload `BENCH_serve.json` / `BENCH_router.json`",
        "with `serve/*` resp. `router/*` p50/p99, achieved_rps, and",
        "shed/error rates (plus one `ramp<i>/` family per --rate-ramp",
        "step), sanity-gated by this script (loadgen rows are absolute,",
        "so they are shape-checked per family, not floor-diffed — quote",
        "them from the CI artifact).",
        "",
        "| derived metric | value |",
        "|---|---|",
    ]
    for name in sorted(current):
        v = current[name]
        unit = "x" if name.startswith("speedup/") else ""
        lines.append(f"| `{name}` | {v:.2f}{unit} |")
    lines.append("")
    with open(out, "w") as f:
        f.write("\n".join(lines))
    print(f"[perf_compare] rendered {len(current)} rows -> {out}")
    sys.exit(0)

tolerance = float(os.environ.get("MMBSGD_PERF_TOLERANCE", "0.20"))
warn_only = os.environ.get("MMBSGD_PERF_WARN_ONLY", "") not in ("", "0")

loadgen_rows = {n: v for n, v in current.items()
                if n.startswith("serve/") or n.startswith("router/")}
if loadgen_rows and not any(n.startswith("speedup/") for n in current):
    # A loadgen artifact (line/http `serve/*` rows or `--mode router`
    # `router/*` rows, plus one `<prefix>/ramp<i>/*` family per
    # --rate-ramp step): no committed speedup floors apply; gate the
    # shape of every family instead.  A family is everything up to the
    # metric leaf — "serve", "router", "router/ramp2", ...
    failures = []

    def gate(cond, msg):
        tag = "ok      " if cond else "BAD     "
        print(f"  {tag} {msg}")
        if not cond:
            failures.append(msg)

    families = {}
    for name, v in loadgen_rows.items():
        fam, _, leaf = name.rpartition("/")
        families.setdefault(fam, {})[leaf] = v
    print(f"[perf_compare] {current_path}: loadgen artifact "
          f"({len(loadgen_rows)} rows, {len(families)} families), sanity-gating")
    gate(any(fam in ("serve", "router") for fam in families),
         "has an aggregate serve/ or router/ family")
    for fam in sorted(families):
        rows = families[fam]
        p50 = rows.get("p50_ns", 0.0)
        p99 = rows.get("p99_ns", 0.0)
        gate(p50 > 0, f"{fam}/p50_ns positive ({p50:.0f})")
        gate(p50 <= p99, f"{fam}/p50_ns <= {fam}/p99_ns ({p50:.0f} vs {p99:.0f})")
        rates = ["shed_rate"] if "ramp" in fam else ["shed_rate", "error_rate"]
        for rate in rates:
            v = rows.get(rate, -1.0)
            gate(0.0 <= v <= 1.0, f"{fam}/{rate} in [0,1] ({v:.4f})")
        rps = rows.get("achieved_rps", 0.0)
        gate(rps > 0, f"{fam}/achieved_rps positive ({rps:.1f})")
        if "ramp" not in fam:
            gate(rows.get("requests", 0.0) >= 1,
                 f"{fam}/requests >= 1 ({rows.get('requests', 0.0):.0f})")
    if failures:
        print(f"[perf_compare] {len(failures)} bad loadgen row(s):", file=sys.stderr)
        for f_ in failures:
            print(f"  {f_}", file=sys.stderr)
        if warn_only:
            print("[perf_compare] MMBSGD_PERF_WARN_ONLY set: not failing",
                  file=sys.stderr)
            sys.exit(0)
        sys.exit(1)
    print("[perf_compare] loadgen artifact is sane")
    sys.exit(0)

baseline = load(os.environ["BASELINE"])

failures = []
print(f"[perf_compare] {current_path} vs {os.environ['BASELINE']} "
      f"(tolerance {tolerance:.0%})")
for name in sorted(baseline):
    if not name.startswith("speedup/"):
        continue
    floor = baseline[name]
    got = current.get(name)
    if got is None:
        failures.append(f"{name}: committed ({floor:.2f}x) but missing from current run")
        print(f"  MISSING  {name}  (baseline {floor:.2f}x)")
        continue
    ok = got >= floor * (1.0 - tolerance)
    tag = "ok      " if ok else "REGRESS "
    print(f"  {tag} {name}  {got:.2f}x vs baseline {floor:.2f}x")
    if not ok:
        failures.append(f"{name}: {got:.2f}x < {floor:.2f}x * {1.0 - tolerance:.2f}")
extra = sorted(n for n in current if n.startswith("speedup/") and n not in baseline)
for name in extra:
    print(f"  new      {name}  {current[name]:.2f}x (not in baseline)")

if failures:
    print(f"[perf_compare] {len(failures)} regression(s):", file=sys.stderr)
    for f_ in failures:
        print(f"  {f_}", file=sys.stderr)
    if warn_only:
        print("[perf_compare] MMBSGD_PERF_WARN_ONLY set: not failing", file=sys.stderr)
        sys.exit(0)
    sys.exit(1)
print("[perf_compare] all committed speedups hold")
PY

//! A/B serving example: two model variants (a generous-budget champion
//! and a tight-budget challenger) behind one [`ModelRegistry`] with a
//! weighted 90/10 [`RouteSpec`].  Requests carry user keys; the seeded
//! routing hash pins each user to one arm — deterministically, so
//! re-running this binary reproduces the exact same assignment — and
//! the per-arm accuracy comparison is a real online A/B readout.
//!
//! This is the budget-maintenance story end to end: the paper makes
//! tight-budget models cheap to *train*; the registry makes them cheap
//! to *try* against the incumbent on live traffic.
//!
//! Run: `cargo run --release --example serve_ab`

use mmbsgd::config::TrainConfig;
use mmbsgd::data::synth::{dataset, SynthSpec};
use mmbsgd::runtime::NativeBackend;
use mmbsgd::serve::{BatchEngine, ModelRegistry, RouteSpec, ShedPolicy};
use mmbsgd::solver::bsgd;
use std::collections::BTreeMap;

fn main() {
    let spec = SynthSpec::phishing_like(0.5);
    let split = dataset(&spec, 5);
    let train = |budget: usize, seed: u64| {
        let cfg = TrainConfig {
            lambda: TrainConfig::lambda_from_c(spec.c, split.train.len()),
            gamma: spec.gamma,
            budget,
            mergees: 4,
            seed,
            ..TrainConfig::default()
        };
        bsgd::train(&split.train, &cfg).expect("valid config").model
    };
    let champion = train(256, 2);
    let challenger = train(64, 3);
    println!(
        "champion: {} SVs (offline acc {:.2}%) | challenger: {} SVs (offline acc {:.2}%)",
        champion.svs.len(),
        100.0 * champion.accuracy(&split.test),
        challenger.svs.len(),
        100.0 * challenger.accuracy(&split.test),
    );

    // One backend serves both models; the route sends 90% of keys to
    // the champion, 10% to the tight-budget challenger.
    let mut registry = ModelRegistry::new(Box::new(NativeBackend::new()), 42);
    registry.insert("champion", champion).expect("valid model");
    registry.insert("challenger", challenger).expect("valid model");
    registry
        .set_route(
            RouteSpec::new(vec![("champion".into(), 9), ("challenger".into(), 1)])
                .expect("valid route"),
        )
        .expect("both arms loaded");

    let mut engine = BatchEngine::new(64, 512, ShedPolicy::Reject);
    let test = &split.test;
    let mut per_arm: BTreeMap<String, (usize, usize)> = BTreeMap::new(); // hits, total
    let mut i = 0;
    while i < test.len() {
        let hi = (i + 64).min(test.len());
        for r in i..hi {
            // key per simulated user: the same user always hits the
            // same arm (sticky assignment, no rand)
            let key = format!("user-{}", r % 997);
            engine
                .submit(&registry, Some(&key), test.x.row(r).to_vec())
                .expect("queue sized for the burst");
        }
        for ((_, res), r) in engine.flush(&mut registry).into_iter().zip(i..hi) {
            let d = res.expect("in-dimension request");
            let label = if d.value >= 0.0 { 1.0 } else { -1.0 };
            let entry = per_arm.entry(d.model).or_insert((0, 0));
            entry.1 += 1;
            if label == test.y[r] {
                entry.0 += 1;
            }
        }
        i = hi;
    }
    println!("\nonline A/B readout over {} requests:", test.len());
    for (arm, (hits, total)) in &per_arm {
        println!(
            "  {arm:<12} {total:>6} requests ({:>5.1}% of traffic) | online acc {:.2}%",
            100.0 * *total as f64 / test.len() as f64,
            100.0 * *hits as f64 / (*total).max(1) as f64,
        );
    }
    let stats = engine.stats();
    println!(
        "\nengine: {} margins passes, mean {:.1} rows/pass (two arms share each burst)",
        stats.batches,
        stats.rows as f64 / stats.batches.max(1) as f64
    );
}

//! Serving example: train once, load the model into a [`ModelRegistry`]
//! behind a [`BatchEngine`] micro-batcher, and serve classification
//! requests one query at a time — the engine coalesces whatever is
//! pending into single tiled margins passes — reporting latency
//! percentiles, throughput, and the achieved micro-batch size.
//!
//! Models trained by `mmbsgd train --save model.txt` serve the same way
//! (`SvmModel::load` + `ModelRegistry::insert`), and `mmbsgd serve`
//! wraps exactly this pipeline in a TCP line protocol; this example
//! trains its own small model so it runs self-contained.  For weighted
//! two-model A/B serving see `examples/serve_ab.rs`.
//!
//! Run: `cargo run --release --example serve_classify [burst_size]`

use mmbsgd::config::TrainConfig;
use mmbsgd::data::synth::{dataset, SynthSpec};
use mmbsgd::serve::{BatchEngine, ModelRegistry, RouteSpec, ShedPolicy};
use mmbsgd::solver::bsgd;
use mmbsgd::util::stats::percentile;
use std::time::Instant;

fn main() {
    let burst: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let spec = SynthSpec::phishing_like(0.5);
    let split = dataset(&spec, 5);
    let cfg = TrainConfig {
        lambda: TrainConfig::lambda_from_c(spec.c, split.train.len()),
        gamma: spec.gamma,
        budget: 256,
        mergees: 4,
        seed: 2,
        ..TrainConfig::default()
    };
    let out = bsgd::train(&split.train, &cfg).expect("valid config");
    println!(
        "model: {} SVs, trained in {:.2}s, test acc {:.2}%",
        out.model.svs.len(),
        out.train_seconds,
        100.0 * out.model.accuracy(&split.test)
    );

    // The registry owns model + backend (one backend no matter how many
    // models), folds the coefficient scale once, and prebuilds the
    // tile far-skip bounds; the engine batches requests through it.
    let mut registry = ModelRegistry::new(
        mmbsgd::coordinator::build_backend(mmbsgd::config::BackendChoice::Native)
            .expect("native backend"),
        1,
    );
    let version = registry.insert("classifier", out.model).expect("valid model");
    registry.set_route(RouteSpec::single("classifier")).expect("model is loaded");
    println!("serving classifier@v{version} through the micro-batch engine");

    let mut engine = BatchEngine::new(burst, 4 * burst, ShedPolicy::Reject);

    // Request stream: test points arrive in bursts of `burst` single
    // queries (what a loaded server sees between two margins passes);
    // each flush answers the whole burst in one tiled pass.
    let test = &split.test;
    let mut latencies_ms = Vec::new();
    let mut served = 0usize;
    let mut correct = 0usize;
    let t0 = Instant::now();
    let mut i = 0;
    while i < test.len() {
        let hi = (i + burst).min(test.len());
        let t1 = Instant::now();
        let ids: Vec<u64> = (i..hi)
            .map(|r| {
                engine
                    .submit(&registry, Some(&format!("req-{r}")), test.x.row(r).to_vec())
                    .expect("queue sized for the burst")
            })
            .collect();
        let answers = engine.flush(&mut registry);
        latencies_ms.push(t1.elapsed().as_secs_f64() * 1e3);
        assert_eq!(answers.len(), ids.len());
        for ((_, res), r) in answers.into_iter().zip(i..hi) {
            let decision = res.expect("in-dimension request").value;
            let label = if decision >= 0.0 { 1.0 } else { -1.0 };
            if label == test.y[r] {
                correct += 1;
            }
        }
        served += hi - i;
        i = hi;
    }
    let total_s = t0.elapsed().as_secs_f64();
    let stats = engine.stats();
    println!(
        "served {served} points in {} bursts of {burst} | accuracy {:.2}%",
        latencies_ms.len(),
        100.0 * correct as f64 / served as f64
    );
    println!(
        "micro-batches: {} passes, mean {:.1} rows/pass | shed {}",
        stats.batches,
        stats.rows as f64 / stats.batches.max(1) as f64,
        stats.shed
    );
    println!(
        "latency per burst: p50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms | throughput {:.0} pts/s",
        percentile(&latencies_ms, 50.0),
        percentile(&latencies_ms, 95.0),
        percentile(&latencies_ms, 99.0),
        served as f64 / total_s
    );
}

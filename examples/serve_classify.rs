//! Serving example: train once, wrap the model in a [`Predictor`]
//! serving handle (XLA runtime when artifacts are present, native
//! fallback otherwise), and serve classification requests in batches,
//! reporting latency percentiles and throughput.
//!
//! Models trained by `mmbsgd train --save model.txt` can be served the
//! same way (`SvmModel::load` + `Predictor::new`); this example trains
//! its own small model so it runs self-contained.
//!
//! Run: `cargo run --release --example serve_classify [batch_size]`

use mmbsgd::config::TrainConfig;
use mmbsgd::data::synth::{dataset, SynthSpec};
use mmbsgd::data::DenseMatrix;
use mmbsgd::runtime::{ArtifactRegistry, Backend, NativeBackend, XlaBackend};
use mmbsgd::serve::Predictor;
use mmbsgd::solver::bsgd;
use mmbsgd::util::stats::percentile;
use std::time::Instant;

fn main() {
    let batch: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(64);
    let spec = SynthSpec::phishing_like(0.5);
    let split = dataset(&spec, 5);
    let cfg = TrainConfig {
        lambda: TrainConfig::lambda_from_c(spec.c, split.train.len()),
        gamma: spec.gamma,
        budget: 256,
        mergees: 4,
        seed: 2,
        ..TrainConfig::default()
    };
    let out = bsgd::train(&split.train, &cfg).expect("valid config");
    println!(
        "model: {} SVs, trained in {:.2}s, test acc {:.2}%",
        out.model.svs.len(),
        out.train_seconds,
        100.0 * out.model.accuracy(&split.test)
    );

    let backend: Box<dyn Backend> = match XlaBackend::new(&ArtifactRegistry::default_dir()) {
        Ok(b) => {
            println!("serving through PJRT (AOT artifacts)");
            Box::new(b)
        }
        Err(e) => {
            println!("no artifacts ({e}); serving natively");
            Box::new(NativeBackend::new())
        }
    };
    // The Predictor owns model + backend, folds the coefficient scale
    // once, and serves every request through the batched margins path.
    let mut served_model = Predictor::new(out.model, backend).expect("valid model");

    // Warmup: the first artifact call pays one-time PJRT compilation;
    // real deployments compile at startup, so exclude it from latency.
    {
        let warm = DenseMatrix::from_rows(vec![vec![0.0f32; split.test.dim()]]);
        let _ = served_model.decision_batch(&warm).expect("dim matches");
    }

    // Request stream: test points in `batch`-sized requests.
    let test = &split.test;
    let mut latencies_ms = Vec::new();
    let mut served = 0usize;
    let mut correct = 0usize;
    let t0 = Instant::now();
    let mut i = 0;
    while i < test.len() {
        let hi = (i + batch).min(test.len());
        let rows: Vec<Vec<f32>> = (i..hi).map(|r| test.x.row(r).to_vec()).collect();
        let q = DenseMatrix::from_rows(rows);
        let t1 = Instant::now();
        let labels = served_model.predict_batch(&q).expect("dim matches");
        latencies_ms.push(t1.elapsed().as_secs_f64() * 1e3);
        for (k, &pred) in labels.iter().enumerate() {
            if pred == test.y[i + k] {
                correct += 1;
            }
        }
        served += hi - i;
        i = hi;
    }
    let total_s = t0.elapsed().as_secs_f64();
    println!(
        "served {served} points in {} requests of {batch} | accuracy {:.2}%",
        latencies_ms.len(),
        100.0 * correct as f64 / served as f64
    );
    println!(
        "latency per request: p50 {:.3} ms  p95 {:.3} ms  p99 {:.3} ms | throughput {:.0} pts/s",
        percentile(&latencies_ms, 50.0),
        percentile(&latencies_ms, 95.0),
        percentile(&latencies_ms, 99.0),
        served as f64 / total_s
    );
}

//! Fleet rollout example: a staged model rollout across two replicas
//! behind the consistent-hash router, with the feedback-driven
//! auto-rollback safety net catching a botched release.
//!
//! The arc:
//!
//! 1. train a champion, package it as a versioned, checksummed
//!    artifact, and verify the bundle round-trips through disk;
//! 2. bring up two replicas (`serve_fleet`) and the router
//!    (`run_router`), push + activate v1 fleet-wide;
//! 3. send keyed traffic with label feedback through the router —
//!    sticky per-user assignment, healthy accuracy window;
//! 4. push a "botched re-export" as v2 (same weights, corrupted bias —
//!    every checksum passes, the *function* is wrong);
//! 5. the feedback window degrades, `maybe_auto_rollback` fires, and
//!    every replica is back on v1 — no human in the loop.
//!
//! Run: `cargo run --release --example fleet_rollout`

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::Path;
use std::time::Duration;

use mmbsgd::config::TrainConfig;
use mmbsgd::data::synth::{dataset, SynthSpec};
use mmbsgd::data::Split;
use mmbsgd::fleet::{run_router, Artifact, Controller, Provenance, ReplicaState, RouterOptions};
use mmbsgd::model::SvmModel;
use mmbsgd::runtime::NativeBackend;
use mmbsgd::serve::{serve_fleet, ModelRegistry, ServeOptions};

fn replica(listener: TcpListener, dir: &Path) {
    let mut rep = ReplicaState::new(dir).expect("replica dir");
    let reg = ModelRegistry::new(Box::new(NativeBackend::new()), 7);
    serve_fleet(listener, reg, &ServeOptions::default(), &mut rep).expect("replica serve");
}

fn bind() -> (TcpListener, SocketAddr) {
    let l = TcpListener::bind("127.0.0.1:0").expect("bind");
    let a = l.local_addr().expect("addr");
    (l, a)
}

/// One line in, one line out, over a fresh connection.
fn ask(addr: SocketAddr, line: &str) -> String {
    let s = TcpStream::connect(addr).expect("connect");
    s.set_nodelay(true).ok();
    let mut w = s.try_clone().expect("clone");
    let mut r = BufReader::new(s);
    writeln!(w, "{line}").expect("send");
    w.flush().expect("flush");
    let mut reply = String::new();
    r.read_line(&mut reply).expect("reply");
    reply.trim_end().to_string()
}

/// Keyed predict + label feedback for `n` test rows through the
/// router; returns the online accuracy the fleet actually observed.
fn traffic(router: SocketAddr, split: &Split, n: usize) -> f64 {
    let s = TcpStream::connect(router).expect("router connect");
    s.set_nodelay(true).ok();
    let mut w = s.try_clone().expect("clone");
    let mut r = BufReader::new(s);
    let mut ask = |line: &str| -> String {
        writeln!(w, "{line}").expect("send");
        w.flush().expect("flush");
        let mut reply = String::new();
        r.read_line(&mut reply).expect("reply");
        reply.trim_end().to_string()
    };
    let mut hits = 0usize;
    for i in 0..n.min(split.test.len()) {
        let row: Vec<String> =
            split.test.x.row(i).iter().map(|v| v.to_string()).collect();
        let row = row.join(" ");
        let key = format!("user-{}", i % 23); // sticky per-user shard
        let pred = ask(&format!("predict key={key} {row}"));
        assert!(pred.starts_with("ok "), "{pred}");
        let label: f64 =
            pred.split_ascii_whitespace().nth(1).expect("label").parse().expect("±1");
        if label == split.test.y[i] {
            hits += 1;
        }
        // the ground truth arrives as feedback — this is what fills
        // each replica's accuracy window (the auto-rollback signal)
        let truth = if split.test.y[i] > 0.0 { "+1" } else { "-1" };
        let fb = ask(&format!("feedback key={key} {truth} {row}"));
        assert!(fb.starts_with("ok "), "{fb}");
    }
    hits as f64 / n.min(split.test.len()) as f64
}

fn main() {
    // -- train + package ------------------------------------------------
    let spec = SynthSpec::phishing_like(0.5);
    let split = dataset(&spec, 5);
    let cfg = TrainConfig {
        lambda: TrainConfig::lambda_from_c(spec.c, split.train.len()),
        gamma: spec.gamma,
        budget: 128,
        mergees: 4,
        seed: 2,
        ..TrainConfig::default()
    };
    let champ = mmbsgd::solver::bsgd::train(&split.train, &cfg).expect("valid config").model;
    println!(
        "trained champ: {} SVs, offline acc {:.2}%",
        champ.svs.len(),
        100.0 * champ.accuracy(&split.test)
    );

    let scratch =
        std::env::temp_dir().join(format!("mmbsgd_fleet_rollout_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&scratch);
    std::fs::create_dir_all(&scratch).expect("scratch dir");

    let v1 = Artifact::wrap("champ", 1, &champ, Provenance::from_config(&cfg), "lut", "auto")
        .expect("package v1");
    let bundle = scratch.join("champ-v1.artifact");
    v1.save(&bundle).expect("save");
    let verified = Artifact::load(&bundle).expect("checksums + footer hold");
    println!(
        "packaged {}@v{} -> {} ({} bytes, dim={}, nsv={}, lambda={})",
        verified.name,
        verified.version,
        bundle.display(),
        std::fs::metadata(&bundle).expect("meta").len(),
        verified.dim,
        verified.nsv,
        verified.provenance.get("lambda").unwrap_or("?"),
    );

    // the botched re-export: a sign-flipped dual (every alpha and the
    // bias negated — the classic label-convention slip).  The bundle is
    // byte-perfect and every checksum passes; only live feedback can
    // catch that the *function* is wrong.
    let mut botched = SvmModel::new(champ.svs.dim(), champ.gamma);
    for j in 0..champ.svs.len() {
        botched.svs.push(champ.svs.point(j), -champ.svs.alpha(j));
    }
    botched.bias = -champ.bias;
    let v2 = Artifact::wrap("champ", 2, &botched, Provenance::from_config(&cfg), "lut", "auto")
        .expect("package v2");

    // -- bring up the fleet --------------------------------------------
    let (l0, a0) = bind();
    let (l1, a1) = bind();
    let (lr, ar) = bind();
    let (d0, d1) = (scratch.join("rep0"), scratch.join("rep1"));
    let eps = vec![a0.to_string(), a1.to_string()];
    std::thread::scope(|s| {
        s.spawn(|| replica(l0, &d0));
        s.spawn(|| replica(l1, &d1));
        let ropts = RouterOptions {
            seed: 42,
            vnodes: 64,
            timeout: Duration::from_secs(5),
            probe_every: Duration::from_secs(60),
        };
        let reps = eps.clone();
        let rh = s.spawn(move || run_router(lr, reps, &ropts).expect("router"));

        let mut ctl = Controller::new(eps.clone(), Duration::from_secs(5));
        println!("\npush + activate v1:");
        for o in ctl.push(&v1, true) {
            println!("  {} -> {:?}", o.endpoint, o.result);
            assert_eq!(o.result, Ok(1));
        }

        let acc = traffic(ar, &split, 120);
        println!("v1 online accuracy through the router: {:.1}%", 100.0 * acc);
        match ctl.maybe_auto_rollback("champ", 0.75) {
            None => println!("auto-rollback guard: quiet (window healthy)"),
            Some(_) => println!("auto-rollback guard: fired on v1 (unlucky shard window)"),
        }

        println!("\npush + activate v2 (the botched re-export):");
        for o in ctl.push(&v2, true) {
            println!("  {} -> {:?}", o.endpoint, o.result);
            assert_eq!(o.result, Ok(2));
        }
        let acc = traffic(ar, &split, 120);
        println!("v2 online accuracy through the router: {:.1}%", 100.0 * acc);

        match ctl.maybe_auto_rollback("champ", 0.75) {
            Some(outs) => {
                println!("auto-rollback guard: FIRED (window below 75%)");
                for o in outs {
                    println!("  {} rolled back -> {:?}", o.endpoint, o.result);
                }
            }
            None => println!("auto-rollback guard: window still above threshold"),
        }

        println!("\nfleet status after the rollout:");
        for (ep, line) in ctl.status() {
            println!("  {ep}: {}", line.expect("status"));
        }

        // orderly shutdown: replicas first (direct — the router refuses
        // control verbs), then the router itself
        for &a in &[a0, a1] {
            assert_eq!(ask(a, "shutdown"), "ok bye");
        }
        assert_eq!(ask(ar, "shutdown"), "ok bye");
        let report = rh.join().expect("router thread");
        println!(
            "\nrouter report: {} connections, {} forwarded, {} retried, {} rejected",
            report.connections, report.forwarded, report.retried, report.rejected
        );
    });
    let _ = std::fs::remove_dir_all(&scratch);
}

//! End-to-end driver: full-scale ADULT twin (32 561 training points,
//! the paper's flagship dataset), classic BSGD (M=2) vs multi-merge
//! (M=5), with a live accuracy curve and merge-time accounting.
//!
//! This is the system-level validation run recorded in EXPERIMENTS.md:
//! it exercises the entire stack — synthetic data pipeline, the BSGD
//! coordinator, multi-merge maintenance (through the configured
//! backend), timed phase accounting, batched evaluation — at paper
//! scale.
//!
//! Run:   cargo run --release --example train_adult [scale] [backend]
//! e.g.:  cargo run --release --example train_adult 1.0 native
//!        cargo run --release --example train_adult 0.25 hybrid

use mmbsgd::config::{BackendChoice, TrainConfig};
use mmbsgd::coordinator::build_backend;
use mmbsgd::data::synth::{dataset, SynthSpec};
use mmbsgd::solver::bsgd;
use mmbsgd::solver::NoopObserver;

fn main() {
    let scale: f64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(1.0);
    let backend_name = std::env::args().nth(2).unwrap_or_else(|| "native".into());
    let backend_choice = BackendChoice::parse(&backend_name).expect("backend: native|xla|hybrid");

    let spec = SynthSpec::adult_like(scale);
    let split = dataset(&spec, 1);
    println!(
        "ADULT twin @scale {scale}: {} train / {} test, d={}, backend={backend_name}",
        split.train.len(),
        split.test.len(),
        split.train.dim()
    );

    let budget = ((1200.0 * scale) as usize).clamp(32, 4096);
    let base = TrainConfig {
        lambda: TrainConfig::lambda_from_c(spec.c, split.train.len()),
        gamma: spec.gamma,
        budget,
        epochs: 1,
        seed: 1,
        eval_every: (split.train.len() / 8).max(1),
        backend: backend_choice,
        ..TrainConfig::default()
    };

    for m in [2usize, 5] {
        let mut cfg = base.clone();
        cfg.mergees = m;
        let mut backend = build_backend(cfg.backend).expect("backend");
        println!("\n--- M = {m} (B = {budget}) ---");
        let out = bsgd::train_full(
            &split.train,
            &cfg,
            backend.as_mut(),
            Some(&split.test),
            &mut NoopObserver,
        )
        .expect("valid config");
        println!("accuracy curve (step, acc%, #SV, elapsed s):");
        for p in &out.history {
            println!(
                "  {:>7}  {:>6.2}  {:>5}  {:>7.2}",
                p.step,
                100.0 * p.accuracy,
                p.n_svs,
                p.elapsed_s
            );
        }
        let acc = bsgd::evaluate(&out.model, backend.as_mut(), &split.test);
        println!(
            "final: {:.2}s train | {:.2}% test acc | merge fraction {:.1}% | \
             {} maintenance events | mean wd {:.3e}",
            out.train_seconds,
            100.0 * acc,
            100.0 * out.merge_fraction(),
            out.maintenance_events,
            out.mean_weight_degradation,
        );
        println!("phase times: {}", out.times.summary());
    }
}

//! Checkpoint/resume example: interrupt a training run mid-epoch,
//! serialize the complete session state, resume it in a *fresh process
//! state* (new session, new backend), and verify the final model is
//! bit-identical to an uninterrupted run — the contract long-running
//! and preemptible training jobs rely on.
//!
//! Run: `cargo run --release --example checkpoint_resume`

use mmbsgd::config::TrainConfig;
use mmbsgd::data::synth::{dataset, SynthSpec};
use mmbsgd::runtime::NativeBackend;
use mmbsgd::solver::{NoopObserver, TrainSession};

fn main() {
    let spec = SynthSpec::adult_like(0.05);
    let split = dataset(&spec, 1);
    let cfg = TrainConfig {
        lambda: TrainConfig::lambda_from_c(spec.c, split.train.len()),
        gamma: spec.gamma,
        budget: 96,
        mergees: 4,
        epochs: 2,
        seed: 13,
        ..TrainConfig::default()
    };
    println!(
        "ADULT twin @5%: {} train, B={} M={} epochs={}",
        split.train.len(),
        cfg.budget,
        cfg.mergees,
        cfg.epochs
    );

    // Reference: uninterrupted run.
    let mut be_ref = NativeBackend::new();
    let mut reference = TrainSession::new(cfg.clone(), &mut be_ref).expect("valid config");
    while reference.epochs_done() < cfg.epochs as u64 {
        reference.partial_fit(&split.train).expect("train");
    }
    let reference = reference.finish();

    // Interrupted run: stop mid-epoch-one, checkpoint, throw the
    // session away, resume from the blob, and train to completion.
    let interrupt_at = split.train.len() as u64 + split.train.len() as u64 / 3;
    let mut be_a = NativeBackend::new();
    let mut first = TrainSession::new(cfg.clone(), &mut be_a).expect("valid config");
    let mut remaining = interrupt_at;
    while remaining > 0 {
        let before = first.steps();
        first.run_epoch(&split.train, None, &mut NoopObserver, remaining).expect("train");
        remaining -= first.steps() - before;
    }
    let blob = first.checkpoint();
    println!(
        "interrupted at step {} (mid-epoch, {} samples left); checkpoint = {} bytes",
        first.steps(),
        first.remaining_in_epoch(),
        blob.len()
    );
    drop(first);

    let mut be_b = NativeBackend::new();
    let mut resumed = TrainSession::resume(&blob, &mut be_b).expect("valid checkpoint");
    while resumed.epochs_done() < cfg.epochs as u64 {
        resumed.partial_fit(&split.train).expect("train");
    }
    let resumed = resumed.finish();

    assert_eq!(resumed.steps, reference.steps);
    assert_eq!(resumed.margin_violations, reference.margin_violations);
    assert_eq!(resumed.maintenance_events, reference.maintenance_events);
    assert_eq!(resumed.model.svs.points_flat(), reference.model.svs.points_flat());
    assert_eq!(resumed.model.svs.alphas_vec(), reference.model.svs.alphas_vec());
    assert_eq!(resumed.model.bias.to_bits(), reference.model.bias.to_bits());
    println!(
        "resumed run: {} steps, {} SVs, {} maintenance events — bit-identical to uninterrupted",
        resumed.steps,
        resumed.model.svs.len(),
        resumed.maintenance_events
    );
    println!(
        "test accuracy: resumed {:.2}% vs uninterrupted {:.2}%",
        100.0 * resumed.model.accuracy(&split.test),
        100.0 * reference.model.accuracy(&split.test)
    );
}

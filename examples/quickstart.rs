//! Quickstart: train a multi-merge BSGD SVM on a synthetic IJCNN twin
//! and report accuracy vs the classic BSGD baseline.
//!
//! Run: `cargo run --release --example quickstart`

use mmbsgd::config::TrainConfig;
use mmbsgd::data::synth::{dataset, SynthSpec};
use mmbsgd::solver::bsgd;

fn main() {
    // 1. Data: a statistical twin of IJCNN (50k × 22 at scale 1.0; we use
    //    10% here so the example finishes in seconds).
    let spec = SynthSpec::ijcnn_like(0.1);
    let split = dataset(&spec, 42);
    println!("dataset {}: {} train / {} test, d={}",
        spec.name, split.train.len(), split.test.len(), split.train.dim());

    // 2. Config: the paper's tuned hyperparameters; budget B=64 — small
    //    enough that maintenance fires constantly (the regime budgets
    //    are for; the unbudgeted model needs ~4x more SVs here).
    let cfg = TrainConfig {
        lambda: TrainConfig::lambda_from_c(spec.c, split.train.len()),
        gamma: spec.gamma,
        budget: 64,
        epochs: 1,
        seed: 7,
        ..TrainConfig::default()
    };

    // 3. Train classic BSGD (M=2) and multi-merge (M=5); same stream.
    for m in [2usize, 5] {
        let mut c = cfg.clone();
        c.mergees = m;
        let out = bsgd::train(&split.train, &c).expect("valid config");
        println!(
            "M={m}: {:.2}s  acc {:.2}%  merge-time {:.0}%  maintenance events {}",
            out.train_seconds,
            100.0 * out.model.accuracy(&split.test),
            100.0 * out.merge_fraction(),
            out.maintenance_events,
        );
    }
    println!("(multi-merge: same accuracy, far fewer maintenance events — the paper's claim)");
}

//! Compare all budget-maintenance strategies on one dataset:
//! removal / projection / binary merge / multi-merge cascade / MM-GD.
//!
//! Reproduces the qualitative claims of Wang et al. §4 and the paper's
//! §2.3: removal is erratic, projection is accurate but O(B³)-slow,
//! merging is the sweet spot, and multi-merge keeps the accuracy while
//! cutting the maintenance bill.
//!
//! Run: `cargo run --release --example compare_maintenance`

use mmbsgd::budget::MaintenanceKind;
use mmbsgd::config::TrainConfig;
use mmbsgd::data::synth::{dataset, SynthSpec};
use mmbsgd::solver::bsgd;
use mmbsgd::util::table::{num, Table};

fn main() {
    let spec = SynthSpec::adult_like(0.1);
    let split = dataset(&spec, 3);
    println!(
        "dataset {}: {} train / {} test (ADULT twin @10%)\n",
        spec.name,
        split.train.len(),
        split.test.len()
    );
    let base = TrainConfig {
        lambda: TrainConfig::lambda_from_c(spec.c, split.train.len()),
        gamma: spec.gamma,
        budget: 128,
        epochs: 1,
        seed: 11,
        ..TrainConfig::default()
    };

    let kinds: Vec<(MaintenanceKind, &str)> = vec![
        (MaintenanceKind::Removal, "removal"),
        (MaintenanceKind::Projection, "projection (O(B^3))"),
        (MaintenanceKind::Merge { m: 2 }, "merge M=2 (classic BSGD)"),
        (MaintenanceKind::Merge { m: 4 }, "multi-merge M=4 (Alg.1)"),
        (MaintenanceKind::MergeGd { m: 4 }, "multi-merge M=4 (Alg.2 GD)"),
    ];

    let mut t = Table::new(&[
        "strategy", "train_sec", "accuracy_pct", "maint_events", "mean_wd", "maint_frac_pct",
    ]);
    for (kind, label) in kinds {
        let mut cfg = base.clone();
        cfg.maintenance = Some(kind);
        let out = bsgd::train(&split.train, &cfg).expect("valid config");
        t.row(vec![
            label.to_string(),
            num(out.train_seconds, 3),
            num(100.0 * out.model.accuracy(&split.test), 2),
            out.maintenance_events.to_string(),
            format!("{:.2e}", out.mean_weight_degradation),
            num(100.0 * out.merge_fraction(), 1),
        ]);
    }
    println!("{}", t.render());
}
